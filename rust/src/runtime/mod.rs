//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts and execute them
//! from the Rust mining path.
//!
//! The interchange format is **HLO text** (`artifacts/*.hlo.txt`), written
//! once by `python/compile/aot.py` — see DESIGN.md §5 and
//! /opt/xla-example/README.md for why text (xla_extension 0.5.1 rejects
//! jax ≥ 0.5's 64-bit-id serialized protos). Python never runs at mining
//! time; the Rust binary is self-contained once artifacts exist.
//!
//! The artifact used by the engine is the **dense hot-core counter**
//! (DESIGN.md §2 hardware adaptation): the induced adjacency matrix over
//! the top-degree vertices is counted with an MXU-shaped `A·A ⊙ A`
//! contraction, while the sparse remainder stays on the CPU intersection
//! path.

use crate::graph::{Graph, VertexId};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Default artifact directory, overridable via `KUDU_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("KUDU_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Hot-core side length the artifacts are compiled for (must match
/// `python/compile/aot.py`).
pub const DENSE_N: usize = 256;

/// A compiled dense-core counting executable on the PJRT CPU client.
pub struct DenseCore {
    exe: xla::PjRtLoadedExecutable,
    n: usize,
}

/// Counts returned by the dense core.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DenseCounts {
    /// Triangles entirely inside the hot set.
    pub triangles: u64,
    /// Wedges (3-chains) whose three vertices are all in the hot set.
    pub wedges: u64,
    /// Edges inside the hot set.
    pub edges: u64,
}

impl DenseCore {
    /// Load `dense_core_{n}.hlo.txt` from the artifact directory and
    /// compile it on the PJRT CPU client.
    pub fn load(dir: &Path, n: usize) -> Result<Self> {
        let path = dir.join(format!("dense_core_{n}.hlo.txt"));
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let path_str = path.to_str().context("artifact path is not UTF-8")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("load HLO text from {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile dense-core HLO")?;
        Ok(DenseCore { exe, n })
    }

    /// Load with defaults (artifact dir from env, n = [`DENSE_N`]).
    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir(), DENSE_N)
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Run the counter on a dense f32 adjacency matrix (row-major n×n,
    /// entries 0.0/1.0, zero diagonal, symmetric).
    pub fn count(&self, adj: &[f32]) -> Result<DenseCounts> {
        anyhow::ensure!(adj.len() == self.n * self.n, "adjacency must be n×n");
        let lit = xla::Literal::vec1(adj).reshape(&[self.n as i64, self.n as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (tri, wedge, edge) f32
        // scalars.
        let tuple = result.to_tuple()?;
        anyhow::ensure!(tuple.len() == 3, "expected 3 outputs, got {}", tuple.len());
        let read = |l: &xla::Literal| -> Result<u64> {
            let v = l.to_vec::<f32>()?;
            Ok(v[0].round() as u64)
        };
        Ok(DenseCounts {
            triangles: read(&tuple[0])?,
            wedges: read(&tuple[1])?,
            edges: read(&tuple[2])?,
        })
    }
}

/// Batch size the pair-intersect artifact is compiled for (must match
/// `python/compile/aot.py`).
pub const PAIR_BATCH: usize = 512;

/// The batched bitmap common-neighbour counter
/// (`pair_intersect_{b}x{n}.hlo.txt`): the direct TPU analogue of Kudu's
/// per-pair edge-list intersections, over hot-core bitmap rows.
pub struct PairIntersect {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    n: usize,
}

impl PairIntersect {
    /// Load and compile the artifact.
    pub fn load(dir: &Path, batch: usize, n: usize) -> Result<Self> {
        let path = dir.join(format!("pair_intersect_{batch}x{n}.hlo.txt"));
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let path_str = path.to_str().context("artifact path is not UTF-8")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("load HLO text from {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile pair-intersect HLO")?;
        Ok(PairIntersect { exe, batch, n })
    }

    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir(), PAIR_BATCH, DENSE_N)
    }

    #[inline]
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// |N(u) ∩ N(v)| for each of `batch` pairs, given the pairs' 0/1
    /// bitmap rows over the hot core (row-major `batch × n` each).
    pub fn counts(&self, rows_u: &[f32], rows_v: &[f32]) -> Result<Vec<u64>> {
        anyhow::ensure!(
            rows_u.len() == self.batch * self.n && rows_v.len() == rows_u.len(),
            "rows must be batch×n"
        );
        let dims = [self.batch as i64, self.n as i64];
        let u = xla::Literal::vec1(rows_u).reshape(&dims)?;
        let v = xla::Literal::vec1(rows_v).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[u, v])?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        anyhow::ensure!(tuple.len() == 1, "expected a 1-tuple");
        Ok(tuple[0].to_vec::<f32>()?.into_iter().map(|x| x.round() as u64).collect())
    }
}

/// The hot-vertex set and its dense induced adjacency, extracted from a
/// graph (the skew insight of paper §6.3 applied to compute: the top-K
/// vertices by degree form a small dense core).
pub struct HotCore {
    /// The selected vertices (top-degree), length ≤ n.
    pub vertices: Vec<VertexId>,
    /// Dense row-major n×n f32 adjacency (padded with zeros).
    pub adj: Vec<f32>,
    /// Membership bitmap over the whole graph.
    pub member: Vec<bool>,
    pub n: usize,
}

impl HotCore {
    /// Extract the top-`n`-degree induced subgraph as a dense matrix.
    pub fn extract(g: &Graph, n: usize) -> Self {
        let mut vertices = g.by_degree_desc();
        vertices.truncate(n);
        let mut member = vec![false; g.num_vertices()];
        let mut index = vec![usize::MAX; g.num_vertices()];
        for (i, &v) in vertices.iter().enumerate() {
            member[v as usize] = true;
            index[v as usize] = i;
        }
        let mut adj = vec![0f32; n * n];
        for (i, &v) in vertices.iter().enumerate() {
            for &u in g.neighbors(v) {
                if member[u as usize] {
                    let j = index[u as usize];
                    adj[i * n + j] = 1.0;
                }
            }
        }
        HotCore { vertices, adj, member, n }
    }

    /// True if all of `vs` are in the hot set.
    #[inline]
    pub fn all_hot(&self, vs: &[VertexId]) -> bool {
        vs.iter().all(|&v| self.member[v as usize])
    }

    /// Reference CPU triangle count of the dense core (validates the XLA
    /// path; also the no-artifact fallback).
    pub fn cpu_triangles(&self) -> u64 {
        let n = self.n;
        let mut t = 0u64;
        for i in 0..n {
            for j in (i + 1)..n {
                if self.adj[i * n + j] == 0.0 {
                    continue;
                }
                for k in (j + 1)..n {
                    if self.adj[i * n + k] != 0.0 && self.adj[j * n + k] != 0.0 {
                        t += 1;
                    }
                }
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn hot_core_extraction() {
        let g = gen::planted_hubs(500, 1000, 4, 0.5, 3);
        let hc = HotCore::extract(&g, 16);
        assert_eq!(hc.vertices.len(), 16);
        assert_eq!(hc.adj.len(), 16 * 16);
        // Symmetric, zero diagonal.
        for i in 0..16 {
            assert_eq!(hc.adj[i * 16 + i], 0.0);
            for j in 0..16 {
                assert_eq!(hc.adj[i * 16 + j], hc.adj[j * 16 + i]);
            }
        }
        // The hubs (highest degree) must be members.
        let top = g.by_degree_desc()[0];
        assert!(hc.member[top as usize]);
    }

    #[test]
    fn cpu_triangles_on_known_core() {
        // A 4-clique plus a detached edge: top-4 core = the clique => 4
        // triangles.
        let g = crate::graph::Graph::from_edges(
            6,
            &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3), (4, 5)],
        );
        let hc = HotCore::extract(&g, 4);
        assert_eq!(hc.cpu_triangles(), 4);
    }

    #[test]
    fn all_hot_membership() {
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (0, 2), (0, 3), (1, 2)]);
        let hc = HotCore::extract(&g, 2);
        assert!(hc.all_hot(&[hc.vertices[0]]));
        assert!(!hc.all_hot(&[3]));
    }

    // DenseCore::load is exercised by tests/runtime_integration.rs (needs
    // `make artifacts`).
}
