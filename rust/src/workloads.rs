//! Built-in GPM applications (paper §8.1) and the one-shot runner.
//!
//! * **TC** — triangle counting (edge-induced 3-clique).
//! * **k-MC** — k-motif counting: every connected size-k pattern,
//!   vertex-induced.
//! * **k-CC** — k-clique counting, edge-induced.
//!
//! [`App`] is an ordinary [`GpmApp`] implementation and [`EngineKind`] a
//! parseable selector that resolves to an [`Executor`](crate::session::Executor)
//! — both are thin adapters over the open traits in [`crate::session`].
//! [`run_app`] is the one-shot convenience: it opens a throwaway
//! [`MiningSession`] per call; harnesses that mine several apps or
//! configurations over one graph should open the session themselves so
//! the partitioning is computed once.

use crate::config::RunConfig;
use crate::engine::sink::FnSink;
use crate::engine::KuduEngine;
use crate::graph::Graph;
use crate::metrics::RunStats;
use crate::pattern::brute::Induced;
use crate::pattern::{motifs, Pattern};
use crate::plan::ClientSystem;
#[cfg(feature = "pjrt")]
use crate::runtime::DenseCore;
use crate::runtime::HotCore;
use crate::session::{
    Executor, GThinkerExec, GpmApp, KuduExec, MiningSession, MovingCompExec, ReplicatedExec,
    SingleMachineExec,
};

/// The built-in counting applications.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum App {
    /// Triangle counting.
    Tc,
    /// k-motif counting (vertex-induced, all connected size-k patterns).
    Mc(usize),
    /// k-clique counting.
    Cc(usize),
}

impl GpmApp for App {
    fn name(&self) -> String {
        match self {
            App::Tc => "TC".into(),
            App::Mc(k) => format!("{k}-MC"),
            App::Cc(k) => format!("{k}-CC"),
        }
    }

    fn patterns(&self) -> Vec<Pattern> {
        match self {
            App::Tc => vec![Pattern::triangle()],
            App::Mc(k) => motifs::all_motifs(*k),
            App::Cc(k) => vec![Pattern::clique(*k)],
        }
    }

    fn induced(&self) -> Induced {
        match self {
            App::Mc(_) => Induced::Vertex,
            App::Tc | App::Cc(_) => Induced::Edge,
        }
    }
}

/// Execution model selector: the parseable face of the
/// [`Executor`](crate::session::Executor) implementations (CLI flags,
/// table headers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Kudu with the given client system's plans.
    Kudu(ClientSystem),
    /// G-thinker-like baseline.
    GThinker,
    /// Moving-computation-to-data baseline.
    MovingComp,
    /// Replicated-graph GraphPi-like baseline.
    Replicated,
    /// Single-machine DFS (ignores the machine count).
    SingleMachine,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Kudu(c) => c.name(),
            EngineKind::GThinker => "G-thinker",
            EngineKind::MovingComp => "MovingComp",
            EngineKind::Replicated => "GraphPi(repl)",
            EngineKind::SingleMachine => "single",
        }
    }

    /// Resolve to the corresponding [`Executor`] implementation.
    pub fn executor(&self) -> Box<dyn Executor> {
        match self {
            EngineKind::Kudu(c) => Box::new(KuduExec { client: *c }),
            EngineKind::GThinker => Box::new(GThinkerExec),
            EngineKind::MovingComp => Box::new(MovingCompExec),
            EngineKind::Replicated => Box::new(ReplicatedExec),
            EngineKind::SingleMachine => Box::new(SingleMachineExec),
        }
    }
}

/// One-shot convenience: run `app` on `graph` with `engine` under `cfg`
/// through a throwaway [`MiningSession`]. The session partitions the
/// graph once and reuses it across all the app's patterns (the old entry
/// point re-partitioned per pattern); results are bitwise identical.
pub fn run_app(graph: &Graph, app: App, engine: EngineKind, cfg: &RunConfig) -> RunStats {
    MiningSession::with_config(graph, cfg.clone()).job(&app).executor(engine.executor()).run()
}

/// Hybrid triangle counting: the dense hot-vertex core is counted by the
/// AOT XLA artifact (MXU-shaped `A·A ⊙ A`, see DESIGN.md §2); the CPU
/// engine counts every triangle with at least one cold vertex. Counts are
/// exact and must equal the pure-CPU path (tested). Requires the `pjrt`
/// feature; [`tc_hybrid_cpu`] is the always-available CPU twin.
#[cfg(feature = "pjrt")]
pub fn tc_hybrid(graph: &Graph, cfg: &RunConfig, core: &DenseCore) -> anyhow::Result<RunStats> {
    let hot = HotCore::extract(graph, core.n());
    let dense = core.count(&hot.adj)?;

    // CPU side: count triangles NOT entirely inside the hot set. The
    // bulk-count fast path cannot filter, so use a per-embedding sink.
    let (stats, cold) = count_cold_triangles(graph, cfg, &hot.member);
    let mut out = stats;
    out.counts = vec![dense.triangles + cold];
    Ok(out)
}

/// Pure-CPU hybrid fallback (no artifacts): identical split, dense core
/// counted by the CPU reference — used to test count equality of the
/// decomposition itself.
pub fn tc_hybrid_cpu(graph: &Graph, cfg: &RunConfig, core_n: usize) -> RunStats {
    let hot = HotCore::extract(graph, core_n);
    let dense_tri = hot.cpu_triangles();
    let (stats, cold) = count_cold_triangles(graph, cfg, &hot.member);
    let mut out = stats;
    out.counts = vec![dense_tri + cold];
    out
}

/// Count triangles with at least one vertex outside `member` using the
/// engine's per-embedding sink path. Returns (run stats, cold count).
/// The accumulator is atomic because the engine runs its machines on
/// concurrent host threads. (This sits below the session layer on
/// purpose: the sink borrows `member`, while session sinks are `'static`.)
fn count_cold_triangles(graph: &Graph, cfg: &RunConfig, member: &[bool]) -> (RunStats, u64) {
    use crate::cluster::Transport;
    use crate::partition::PartitionedGraph;
    use std::sync::atomic::{AtomicU64, Ordering};
    let plan = ClientSystem::GraphPi.plan(&Pattern::triangle(), Induced::Edge);
    let pg = PartitionedGraph::new(graph, cfg.num_machines);
    let mut tr = Transport::new(pg, cfg.net);
    let cold_counter = AtomicU64::new(0);
    let mut sinks: Vec<FnSink<Box<dyn FnMut(&[u32]) + Send + '_>>> = Vec::new();
    let stats = KuduEngine::run_with_sinks(
        graph,
        &plan,
        &cfg.engine,
        &cfg.compute,
        &mut tr,
        |_m| {
            let cc = &cold_counter;
            FnSink::new(Box::new(move |vs: &[u32]| {
                if !vs.iter().all(|&v| member[v as usize]) {
                    cc.fetch_add(1, Ordering::Relaxed);
                }
            }) as Box<dyn FnMut(&[u32]) + Send + '_>)
        },
        &mut sinks,
    );
    drop(sinks);
    (stats, cold_counter.load(Ordering::Relaxed))
}

// Heavy under Miri (full engine runs / threads / file I/O): the Miri
// leg covers the light per-module tests and the protocol types.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::pattern::brute;

    #[test]
    fn all_engines_agree_on_tc() {
        let g = gen::rmat(8, 8, 73);
        let cfg = RunConfig::with_machines(4);
        let expect = brute::triangle_count(&g);
        let sess = MiningSession::with_config(&g, cfg);
        for engine in [
            EngineKind::Kudu(ClientSystem::Automine),
            EngineKind::Kudu(ClientSystem::GraphPi),
            EngineKind::GThinker,
            EngineKind::MovingComp,
            EngineKind::Replicated,
            EngineKind::SingleMachine,
        ] {
            let st = sess.job(&App::Tc).executor(engine.executor()).run();
            assert_eq!(st.total_count(), expect, "{}", engine.name());
        }
    }

    #[test]
    fn motif_counts_sum_consistently() {
        let g = gen::erdos_renyi(60, 200, 79);
        let cfg = RunConfig::with_machines(3);
        let st = run_app(&g, App::Mc(3), EngineKind::Kudu(ClientSystem::GraphPi), &cfg);
        assert_eq!(st.counts.len(), 2); // triangle + wedge
        let expect: u64 = motifs::all_motifs(3)
            .iter()
            .map(|p| brute::count_embeddings(&g, p, Induced::Vertex))
            .sum();
        assert_eq!(st.total_count(), expect);
    }

    #[test]
    fn clique_apps() {
        let g = gen::rmat(7, 8, 83);
        let sess = MiningSession::new(&g, 2);
        for k in [4, 5] {
            let expect = brute::count_embeddings(&g, &Pattern::clique(k), Induced::Edge);
            let st = sess.job(&App::Cc(k)).client(ClientSystem::GraphPi).run();
            assert_eq!(st.total_count(), expect, "k={k}");
        }
    }

    #[test]
    fn hybrid_cpu_decomposition_is_exact() {
        let g = gen::planted_hubs(800, 2500, 5, 0.3, 97);
        let cfg = RunConfig::with_machines(2);
        let expect = brute::triangle_count(&g);
        for core_n in [4, 32, 128] {
            let st = tc_hybrid_cpu(&g, &cfg, core_n);
            assert_eq!(st.total_count(), expect, "core_n={core_n}");
        }
    }

    #[test]
    fn app_names() {
        assert_eq!(App::Tc.name(), "TC");
        assert_eq!(App::Mc(3).name(), "3-MC");
        assert_eq!(App::Cc(5).name(), "5-CC");
    }
}
