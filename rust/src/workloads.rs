//! GPM applications (paper §8.1) and engine runners.
//!
//! * **TC** — triangle counting (edge-induced 3-clique).
//! * **k-MC** — k-motif counting: every connected size-k pattern,
//!   vertex-induced.
//! * **k-CC** — k-clique counting, edge-induced.
//!
//! [`run_app`] dispatches an app onto any of the five execution models
//! (Kudu, G-thinker, moving-computation, replicated, single-machine) with
//! a shared configuration, which is exactly what the table harness needs.

use crate::baselines::{GThinker, MovingComputation, Replicated, SingleMachine};
use crate::cluster::Transport;
use crate::config::RunConfig;
use crate::engine::sink::FnSink;
use crate::engine::KuduEngine;
use crate::graph::Graph;
use crate::metrics::{RunStats, Traffic};
use crate::partition::PartitionedGraph;
use crate::pattern::brute::Induced;
use crate::pattern::{motifs, Pattern};
use crate::plan::{ClientSystem, Plan};
#[cfg(feature = "pjrt")]
use crate::runtime::DenseCore;
use crate::runtime::HotCore;

/// A GPM application.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum App {
    /// Triangle counting.
    Tc,
    /// k-motif counting (vertex-induced, all connected size-k patterns).
    Mc(usize),
    /// k-clique counting.
    Cc(usize),
}

impl App {
    pub fn name(&self) -> String {
        match self {
            App::Tc => "TC".into(),
            App::Mc(k) => format!("{k}-MC"),
            App::Cc(k) => format!("{k}-CC"),
        }
    }

    /// The patterns this app mines, with their induced semantics.
    pub fn patterns(&self) -> (Vec<Pattern>, Induced) {
        match self {
            App::Tc => (vec![Pattern::triangle()], Induced::Edge),
            App::Mc(k) => (motifs::all_motifs(*k), Induced::Vertex),
            App::Cc(k) => (vec![Pattern::clique(*k)], Induced::Edge),
        }
    }

    /// Compile plans with the given client system's planner, honouring the
    /// vertical-sharing toggle.
    pub fn plans(&self, client: ClientSystem, vertical_sharing: bool) -> Vec<Plan> {
        let (patterns, induced) = self.patterns();
        patterns
            .iter()
            .map(|p| {
                let plan = client.plan(p, induced);
                if vertical_sharing {
                    plan
                } else {
                    plan.without_vertical_sharing()
                }
            })
            .collect()
    }
}

/// Execution model selector for [`run_app`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Kudu with the given client system's plans.
    Kudu(ClientSystem),
    /// G-thinker-like baseline.
    GThinker,
    /// Moving-computation-to-data baseline.
    MovingComp,
    /// Replicated-graph GraphPi-like baseline.
    Replicated,
    /// Single-machine DFS (ignores the machine count).
    SingleMachine,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Kudu(c) => c.name(),
            EngineKind::GThinker => "G-thinker",
            EngineKind::MovingComp => "MovingComp",
            EngineKind::Replicated => "GraphPi(repl)",
            EngineKind::SingleMachine => "single",
        }
    }
}

/// Run `app` on `graph` with `engine` under `cfg`. Multi-pattern apps run
/// pattern-by-pattern; stats are merged (counts appended, times summed,
/// traffic summed).
pub fn run_app(graph: &Graph, app: App, engine: EngineKind, cfg: &RunConfig) -> RunStats {
    let client = match engine {
        EngineKind::Kudu(c) => c,
        // Baselines all use the GraphPi planner — best plans for everyone,
        // so comparisons isolate the execution model.
        _ => ClientSystem::GraphPi,
    };
    let plans = app.plans(client, cfg.engine.vertical_sharing);
    let mut merged = RunStats::default();
    let mut traffic = Traffic::new(cfg.num_machines);
    for plan in &plans {
        let stats = match engine {
            EngineKind::Kudu(_) => {
                let pg = PartitionedGraph::new(graph, cfg.num_machines);
                let mut tr = Transport::new(pg, cfg.net);
                let s = KuduEngine::run(graph, plan, &cfg.engine, &cfg.compute, &mut tr);
                traffic.merge(&tr.traffic);
                s
            }
            EngineKind::GThinker => {
                let pg = PartitionedGraph::new(graph, cfg.num_machines);
                let mut tr = Transport::new(pg, cfg.net);
                let s = GThinker::run(
                    graph,
                    plan,
                    cfg.engine.threads,
                    cfg.engine.sim_threads,
                    &cfg.compute,
                    &mut tr,
                );
                traffic.merge(&tr.traffic);
                s
            }
            EngineKind::MovingComp => {
                let pg = PartitionedGraph::new(graph, cfg.num_machines);
                let mut tr = Transport::new(pg, cfg.net);
                let s = MovingComputation::run(graph, plan, cfg.engine.threads, &cfg.compute, &mut tr);
                traffic.merge(&tr.traffic);
                s
            }
            EngineKind::Replicated => Replicated::run(
                graph,
                plan,
                cfg.num_machines,
                cfg.engine.threads,
                cfg.engine.sim_threads,
                &cfg.compute,
            ),
            EngineKind::SingleMachine => SingleMachine::run(graph, plan, &cfg.compute),
        };
        merged.absorb(&stats);
    }
    merged
}

/// Hybrid triangle counting: the dense hot-vertex core is counted by the
/// AOT XLA artifact (MXU-shaped `A·A ⊙ A`, see DESIGN.md §2); the CPU
/// engine counts every triangle with at least one cold vertex. Counts are
/// exact and must equal the pure-CPU path (tested). Requires the `pjrt`
/// feature; [`tc_hybrid_cpu`] is the always-available CPU twin.
#[cfg(feature = "pjrt")]
pub fn tc_hybrid(graph: &Graph, cfg: &RunConfig, core: &DenseCore) -> anyhow::Result<RunStats> {
    let hot = HotCore::extract(graph, core.n());
    let dense = core.count(&hot.adj)?;

    // CPU side: count triangles NOT entirely inside the hot set. The
    // bulk-count fast path cannot filter, so use a per-embedding sink.
    let (stats, cold) = count_cold_triangles(graph, cfg, &hot.member);
    let mut out = stats;
    out.counts = vec![dense.triangles + cold];
    Ok(out)
}

/// Pure-CPU hybrid fallback (no artifacts): identical split, dense core
/// counted by the CPU reference — used to test count equality of the
/// decomposition itself.
pub fn tc_hybrid_cpu(graph: &Graph, cfg: &RunConfig, core_n: usize) -> RunStats {
    let hot = HotCore::extract(graph, core_n);
    let dense_tri = hot.cpu_triangles();
    let (stats, cold) = count_cold_triangles(graph, cfg, &hot.member);
    let mut out = stats;
    out.counts = vec![dense_tri + cold];
    out
}

/// Count triangles with at least one vertex outside `member` using the
/// engine's per-embedding sink path. Returns (run stats, cold count).
/// The accumulator is atomic because the engine runs its machines on
/// concurrent host threads.
fn count_cold_triangles(graph: &Graph, cfg: &RunConfig, member: &[bool]) -> (RunStats, u64) {
    use std::sync::atomic::{AtomicU64, Ordering};
    let plan = ClientSystem::GraphPi.plan(&Pattern::triangle(), Induced::Edge);
    let pg = PartitionedGraph::new(graph, cfg.num_machines);
    let mut tr = Transport::new(pg, cfg.net);
    let cold_counter = AtomicU64::new(0);
    let mut sinks: Vec<FnSink<Box<dyn FnMut(&[u32]) + Send + '_>>> = Vec::new();
    let stats = KuduEngine::run_with_sinks(
        graph,
        &plan,
        &cfg.engine,
        &cfg.compute,
        &mut tr,
        |_m| {
            let cc = &cold_counter;
            FnSink::new(Box::new(move |vs: &[u32]| {
                if !vs.iter().all(|&v| member[v as usize]) {
                    cc.fetch_add(1, Ordering::Relaxed);
                }
            }) as Box<dyn FnMut(&[u32]) + Send + '_>)
        },
        &mut sinks,
    );
    drop(sinks);
    (stats, cold_counter.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::pattern::brute;

    #[test]
    fn all_engines_agree_on_tc() {
        let g = gen::rmat(8, 8, 73);
        let cfg = RunConfig::with_machines(4);
        let expect = brute::triangle_count(&g);
        for engine in [
            EngineKind::Kudu(ClientSystem::Automine),
            EngineKind::Kudu(ClientSystem::GraphPi),
            EngineKind::GThinker,
            EngineKind::MovingComp,
            EngineKind::Replicated,
            EngineKind::SingleMachine,
        ] {
            let st = run_app(&g, App::Tc, engine, &cfg);
            assert_eq!(st.total_count(), expect, "{}", engine.name());
        }
    }

    #[test]
    fn motif_counts_sum_consistently() {
        let g = gen::erdos_renyi(60, 200, 79);
        let cfg = RunConfig::with_machines(3);
        let st = run_app(&g, App::Mc(3), EngineKind::Kudu(ClientSystem::GraphPi), &cfg);
        assert_eq!(st.counts.len(), 2); // triangle + wedge
        let expect: u64 = motifs::all_motifs(3)
            .iter()
            .map(|p| brute::count_embeddings(&g, p, Induced::Vertex))
            .sum();
        assert_eq!(st.total_count(), expect);
    }

    #[test]
    fn clique_apps() {
        let g = gen::rmat(7, 8, 83);
        let cfg = RunConfig::with_machines(2);
        for k in [4, 5] {
            let expect = brute::count_embeddings(&g, &Pattern::clique(k), Induced::Edge);
            let st = run_app(&g, App::Cc(k), EngineKind::Kudu(ClientSystem::GraphPi), &cfg);
            assert_eq!(st.total_count(), expect, "k={k}");
        }
    }

    #[test]
    fn hybrid_cpu_decomposition_is_exact() {
        let g = gen::planted_hubs(800, 2500, 5, 0.3, 97);
        let cfg = RunConfig::with_machines(2);
        let expect = brute::triangle_count(&g);
        for core_n in [4, 32, 128] {
            let st = tc_hybrid_cpu(&g, &cfg, core_n);
            assert_eq!(st.total_count(), expect, "core_n={core_n}");
        }
    }

    #[test]
    fn app_names() {
        assert_eq!(App::Tc.name(), "TC");
        assert_eq!(App::Mc(3).name(), "3-MC");
        assert_eq!(App::Cc(5).name(), "5-CC");
    }
}
