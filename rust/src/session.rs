//! The mining-session API: Kudu's public abstraction.
//!
//! The paper's headline claim is a *well-defined abstraction* under which
//! existing single-machine GPM systems run distributed unchanged. This
//! module is that seam, split into three pieces:
//!
//! * [`MiningSession`] — owns the graph, the 1-D partitioning, and the
//!   per-machine owned-vertex lists **once**, shared by every pattern,
//!   query, and executor of the session. (The pre-session entry points
//!   re-partitioned per pattern: a 4-motif-count app partitioned the
//!   graph six times.)
//! * [`GpmApp`] — what to mine: the pattern set, the embedding semantics,
//!   an optional per-unit sink factory for per-embedding processing, and
//!   the result aggregation. The built-in counting apps
//!   ([`crate::workloads::App`]) and the labelled-query app
//!   ([`LabeledQuery`]) are both ordinary implementations.
//! * [`Executor`] — how to mine: one compiled [`Plan`] at a time over the
//!   session's shared cluster state. Implemented by the Kudu engine
//!   ([`KuduExec`]) and all four comparator baselines, so the table
//!   harness selects execution models through one trait instead of an
//!   enum match.
//!
//! Jobs are built fluently:
//!
//! ```no_run
//! use kudu::graph::gen;
//! use kudu::plan::ClientSystem;
//! use kudu::session::MiningSession;
//! use kudu::workloads::App;
//!
//! let g = gen::rmat(10, 10, 42);
//! let session = MiningSession::new(&g, 8);
//! let stats = session
//!     .job(&App::Cc(4))
//!     .client(ClientSystem::Automine)
//!     .vertical_sharing(false)
//!     .run();
//! println!("4-cliques: {}", stats.total_count());
//! ```
//!
//! Every result a job reports — counts, traffic, virtual time — is
//! bitwise identical to the pre-session entry points (property-tested in
//! `tests/session_equivalence.rs`).

use crate::baselines::{GThinker, MovingComputation, Replicated, SingleMachine};
use crate::cluster::Transport;
use crate::config::RunConfig;
use crate::engine::sink::{AppSink, BoxSink, CountSink, EmbeddingSink};
use crate::engine::KuduEngine;
use crate::graph::{Graph, VertexId};
use crate::metrics::RunStats;
use crate::partition::PartitionedGraph;
use crate::pattern::brute::Induced;
use crate::pattern::Pattern;
use crate::plan::{ClientSystem, Plan};
use std::collections::HashSet;
use std::sync::Mutex;

/// Everything one pattern's run hands back to its app for aggregation.
pub struct PatternOutcome {
    /// Index into the app's pattern list.
    pub pattern_idx: usize,
    /// Single-pattern run statistics; `counts` holds one entry (the raw
    /// embedding count reported by the executor).
    pub stats: RunStats,
    /// The finished per-unit sinks, in unit order. Empty for counting apps
    /// (executors bulk-count without materialising sinks).
    pub sinks: Vec<BoxSink>,
}

/// A graph pattern mining application: *what* to mine and what to do with
/// each embedding. Object-safe, so apps are passed as `&dyn GpmApp`;
/// `Sync` because sink factories are invoked from concurrent executor
/// threads.
///
/// The default methods implement a plain counting app — the only code a
/// new counting workload needs is [`GpmApp::name`], [`GpmApp::patterns`],
/// and [`GpmApp::induced`]. Apps that process embeddings (support
/// counting, per-vertex statistics, …) override [`GpmApp::needs_sinks`],
/// [`GpmApp::unit_sink`], and [`GpmApp::aggregate`]; see [`LabeledQuery`]
/// for a complete example.
pub trait GpmApp: Sync {
    /// Display name (table/report headers).
    fn name(&self) -> String;

    /// The patterns this app mines, in reporting order.
    fn patterns(&self) -> Vec<Pattern>;

    /// Embedding semantics shared by all the app's patterns.
    fn induced(&self) -> Induced;

    /// True when the app must see each embedding (via [`GpmApp::unit_sink`])
    /// rather than a bulk count. Sink apps require an executor with
    /// [`Executor::supports_sinks`].
    fn needs_sinks(&self) -> bool {
        false
    }

    /// Per-execution-unit sink factory for pattern `pattern_idx`. A unit
    /// is one scheduler task of a simulated machine (a root mini-batch or
    /// a split-off chunk — see [`crate::engine::task`]); `machine` is the
    /// unit's machine index. Only called when [`GpmApp::needs_sinks`] is
    /// true. Units are reduced in a deterministic order fixed by graph +
    /// config, never by host scheduling.
    fn unit_sink(&self, pattern_idx: usize, machine: usize) -> BoxSink {
        let _ = (pattern_idx, machine);
        Box::new(CountSink::default())
    }

    /// Fold the per-pattern outcomes (in pattern order) into the job's
    /// final statistics. The default appends counts and sums times and
    /// traffic — exactly the multi-pattern merge the counting apps need.
    fn aggregate(&self, outcomes: Vec<PatternOutcome>) -> RunStats {
        let mut merged = RunStats::default();
        for o in &outcomes {
            merged.absorb(&o.stats);
        }
        merged
    }
}

/// Shared per-plan execution context an [`Executor`] runs against: the
/// session's graph, partitioning, and owned-vertex lists, plus the
/// job-resolved configuration and one compiled plan.
pub struct PlanCtx<'s, 'g> {
    pub graph: &'g Graph,
    pub plan: &'s Plan,
    pub cfg: &'s RunConfig,
    /// The session's shared 1-D partitioning (computed once per session).
    pub pg: PartitionedGraph<'g>,
    /// Per-machine owned-vertex lists, unfiltered (computed once per
    /// session; executors apply plan-specific root filters themselves).
    pub roots: &'s [Vec<VertexId>],
}

/// An execution model that can mine one compiled [`Plan`] over the
/// session's shared cluster state. Implemented by the Kudu engine and all
/// four comparator baselines; object-safe so the harnesses select
/// executors dynamically.
pub trait Executor: Send + Sync {
    /// Display name (table headers).
    fn name(&self) -> String;

    /// The client system whose planner compiles this executor's plans.
    /// Baselines use the GraphPi planner — best plans for everyone, so
    /// comparisons isolate the execution model.
    fn client(&self) -> ClientSystem {
        ClientSystem::GraphPi
    }

    /// Mine one plan, counting embeddings. Returns single-pattern stats
    /// with `counts = [n]`.
    fn run_plan(&self, ctx: &PlanCtx<'_, '_>) -> RunStats;

    /// Whether [`Executor::run_plan_with_sinks`] is available (per-
    /// embedding processing). Only the fine-grained Kudu engine exposes
    /// the paper's Algorithm-1 user function; the baselines count only.
    fn supports_sinks(&self) -> bool {
        false
    }

    /// Mine one plan, feeding every embedding through per-unit sinks from
    /// `make_sink`. Returns the stats (counts = sum of sink totals) and
    /// the finished sinks in unit order.
    fn run_plan_with_sinks(
        &self,
        ctx: &PlanCtx<'_, '_>,
        make_sink: &(dyn Fn(usize) -> BoxSink + Sync),
    ) -> (RunStats, Vec<BoxSink>) {
        let _ = (ctx, make_sink);
        panic!(
            "executor '{}' does not support per-embedding sinks; \
             use a sink-capable executor (e.g. KuduExec) for this app",
            self.name()
        );
    }
}

/// The Kudu engine as an [`Executor`], parameterised by the client system
/// whose planner compiles its plans.
pub struct KuduExec {
    pub client: ClientSystem,
}

impl Executor for KuduExec {
    fn name(&self) -> String {
        self.client.name().into()
    }

    fn client(&self) -> ClientSystem {
        self.client
    }

    fn run_plan(&self, ctx: &PlanCtx<'_, '_>) -> RunStats {
        let mut tr = Transport::new(ctx.pg, ctx.cfg.net);
        KuduEngine::run_on_roots(
            ctx.graph,
            ctx.plan,
            &ctx.cfg.engine,
            &ctx.cfg.compute,
            &mut tr,
            ctx.roots,
        )
    }

    fn supports_sinks(&self) -> bool {
        true
    }

    fn run_plan_with_sinks(
        &self,
        ctx: &PlanCtx<'_, '_>,
        make_sink: &(dyn Fn(usize) -> BoxSink + Sync),
    ) -> (RunStats, Vec<BoxSink>) {
        let mut tr = Transport::new(ctx.pg, ctx.cfg.net);
        let mut sinks: Vec<BoxSink> = Vec::new();
        let mut stats = KuduEngine::run_with_sinks_on_roots(
            ctx.graph,
            ctx.plan,
            &ctx.cfg.engine,
            &ctx.cfg.compute,
            &mut tr,
            ctx.roots,
            make_sink,
            &mut sinks,
        );
        stats.counts = vec![sinks.iter().map(|s| s.total()).sum()];
        (stats, sinks)
    }
}

/// G-thinker-like baseline as an [`Executor`].
pub struct GThinkerExec;

impl Executor for GThinkerExec {
    fn name(&self) -> String {
        "G-thinker".into()
    }

    fn run_plan(&self, ctx: &PlanCtx<'_, '_>) -> RunStats {
        let mut tr = Transport::new(ctx.pg, ctx.cfg.net);
        GThinker::run(
            ctx.graph,
            ctx.plan,
            ctx.cfg.engine.threads,
            ctx.cfg.engine.sim_threads,
            &ctx.cfg.engine.comm,
            &ctx.cfg.compute,
            &mut tr,
        )
    }
}

/// Moving-computation-to-data baseline as an [`Executor`].
pub struct MovingCompExec;

impl Executor for MovingCompExec {
    fn name(&self) -> String {
        "MovingComp".into()
    }

    fn run_plan(&self, ctx: &PlanCtx<'_, '_>) -> RunStats {
        let mut tr = Transport::new(ctx.pg, ctx.cfg.net);
        MovingComputation::run(
            ctx.graph,
            ctx.plan,
            ctx.cfg.engine.threads,
            &ctx.cfg.engine.comm,
            &ctx.cfg.compute,
            &mut tr,
        )
    }
}

/// Replicated-graph GraphPi-like baseline as an [`Executor`].
pub struct ReplicatedExec;

impl Executor for ReplicatedExec {
    fn name(&self) -> String {
        "GraphPi(repl)".into()
    }

    fn run_plan(&self, ctx: &PlanCtx<'_, '_>) -> RunStats {
        Replicated::run(
            ctx.graph,
            ctx.plan,
            ctx.cfg.num_machines,
            ctx.cfg.engine.threads,
            ctx.cfg.engine.sim_threads,
            &ctx.cfg.compute,
        )
    }
}

/// Single-machine DFS reference as an [`Executor`] (ignores the machine
/// count).
pub struct SingleMachineExec;

impl Executor for SingleMachineExec {
    fn name(&self) -> String {
        "single".into()
    }

    fn run_plan(&self, ctx: &PlanCtx<'_, '_>) -> RunStats {
        SingleMachine::run(ctx.graph, ctx.plan, &ctx.cfg.compute)
    }
}

/// A mining session: the graph, its 1-D partitioning, and the per-machine
/// owned-vertex lists, computed **once** and shared by every job. Jobs
/// borrow the session immutably, so a session serves any number of apps,
/// executors, and feature ablations without re-partitioning.
pub struct MiningSession<'g> {
    graph: &'g Graph,
    cfg: RunConfig,
    pg: PartitionedGraph<'g>,
    roots: Vec<Vec<VertexId>>,
}

impl<'g> MiningSession<'g> {
    /// Open a session over `graph` partitioned across `machines` simulated
    /// machines, with default configuration.
    pub fn new(graph: &'g Graph, machines: usize) -> Self {
        Self::with_config(graph, RunConfig::with_machines(machines))
    }

    /// Open a session with a full [`RunConfig`]. The partitioning is fixed
    /// by `cfg.num_machines` for the session's lifetime; per-job engine
    /// toggles are overridden on the job builder.
    pub fn with_config(graph: &'g Graph, cfg: RunConfig) -> Self {
        let pg = PartitionedGraph::new(graph, cfg.num_machines);
        let roots = (0..cfg.num_machines).map(|m| pg.owned_vertices(m)).collect();
        MiningSession { graph, cfg, pg, roots }
    }

    pub fn graph(&self) -> &'g Graph {
        self.graph
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    pub fn num_machines(&self) -> usize {
        self.cfg.num_machines
    }

    /// The session's shared partitioning.
    pub fn partitioned(&self) -> &PartitionedGraph<'g> {
        &self.pg
    }

    /// Per-machine owned-vertex lists (the partition-once state).
    pub fn owned_roots(&self) -> &[Vec<VertexId>] {
        &self.roots
    }

    /// Start building a job that mines `app` on this session. Defaults:
    /// the Kudu engine with the GraphPi planner and the session's config.
    pub fn job<'a>(&'a self, app: &'a dyn GpmApp) -> Job<'a, 'g> {
        Job {
            sess: self,
            app,
            exec: Box::new(KuduExec { client: ClientSystem::GraphPi }),
            cfg: self.cfg.clone(),
        }
    }
}

/// Fluent builder for one mining job: an app × an executor × config
/// overrides. Consumed by [`Job::run`].
pub struct Job<'a, 'g> {
    sess: &'a MiningSession<'g>,
    app: &'a dyn GpmApp,
    exec: Box<dyn Executor>,
    cfg: RunConfig,
}

impl<'a, 'g> Job<'a, 'g> {
    /// Mine with the Kudu engine, compiling plans with `client`'s planner.
    pub fn client(mut self, client: ClientSystem) -> Self {
        self.exec = Box::new(KuduExec { client });
        self
    }

    /// Mine with an explicit executor (baselines, custom execution models).
    pub fn executor(mut self, exec: Box<dyn Executor>) -> Self {
        self.exec = exec;
        self
    }

    /// Toggle vertical computation sharing (paper §6.1 / Fig 13).
    pub fn vertical_sharing(mut self, on: bool) -> Self {
        self.cfg.engine.vertical_sharing = on;
        self
    }

    /// Toggle horizontal data sharing (paper §6.2 / Fig 14).
    pub fn horizontal_sharing(mut self, on: bool) -> Self {
        self.cfg.engine.horizontal_sharing = on;
        self
    }

    /// Static-cache size as a fraction of CSR bytes; `0.0` disables.
    pub fn cache_frac(mut self, frac: f64) -> Self {
        self.cfg.engine.cache_frac = frac;
        self
    }

    /// Modeled computation threads per machine (scales virtual time).
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.engine.threads = threads;
        self
    }

    /// Host threads executing the simulation (`0` = all cores). Changes
    /// wall-clock only, never the reported metrics.
    pub fn sim_threads(mut self, threads: usize) -> Self {
        self.cfg.engine.sim_threads = threads;
        self
    }

    /// Scheduler workers per simulated machine (`0` = all cores): the
    /// intra-machine work-stealing width. Like [`Job::sim_threads`], this
    /// changes wall-clock only, never the reported metrics.
    pub fn workers_per_machine(mut self, workers: usize) -> Self {
        self.cfg.engine.workers_per_machine = workers;
        self
    }

    /// Synchronous-fetch escape hatch: `true` bypasses the
    /// message-passing comm subsystem and reads remote partitions
    /// directly through the shared cluster view (the pre-comm
    /// execution). Counts, traffic, and virtual time are bitwise
    /// identical either way — only wall-clock behaviour and the comm
    /// diagnostics (`comm_stall_s`, `peak_in_flight`, `comm_flushes`)
    /// change.
    pub fn sync_fetch(mut self, on: bool) -> Self {
        self.cfg.engine.comm.sync_fetch = on;
        self
    }

    /// In-flight request window of the comm subsystem (max outstanding
    /// logical fetches per machine; must be ≥ 1). `1` with
    /// [`Job::comm_batch_bytes`]`(0)` degenerates to synchronous
    /// blocking round trips — still real messages, just serialised.
    pub fn comm_window(mut self, max_in_flight: usize) -> Self {
        self.cfg.engine.comm.max_in_flight = max_in_flight;
        self
    }

    /// Physical aggregation threshold of the comm subsystem, in modelled
    /// request bytes (`0` = every logical request is its own envelope).
    pub fn comm_batch_bytes(mut self, bytes: u64) -> Self {
        self.cfg.engine.comm.batch_bytes = bytes;
        self
    }

    /// Task-split budgets: frames at `level < levels` hand full child
    /// chunks to the scheduler as new tasks, at most `width` per task.
    /// Changes the (deterministic) task decomposition — and with it
    /// virtual-time granularity — not the mining answer.
    pub fn task_split(mut self, levels: usize, width: usize) -> Self {
        self.cfg.engine.task_split_levels = levels;
        self.cfg.engine.task_split_width = width;
        self
    }

    /// Cap on split-off chunks queued per machine (memory bound; past
    /// it, a child task becomes the spawning worker's next task instead
    /// of queueing).
    pub fn max_live_chunks(mut self, cap: usize) -> Self {
        self.cfg.engine.max_live_chunks = cap;
        self
    }

    /// NUMA sockets per machine (`1` disables NUMA modelling).
    pub fn sockets(mut self, sockets: usize) -> Self {
        self.cfg.engine.sockets = sockets;
        self
    }

    /// Toggle NUMA-aware exploration (Table 7).
    pub fn numa_aware(mut self, on: bool) -> Self {
        self.cfg.engine.numa_aware = on;
        self
    }

    /// Run the job: compile one plan per app pattern with the executor's
    /// client planner, execute each over the session's shared cluster
    /// state, and hand the outcomes to the app for aggregation.
    ///
    /// Multi-pattern apps run pattern-by-pattern; with the default
    /// aggregation, counts append and times/traffic sum — identical to the
    /// pre-session entry points, bit for bit.
    pub fn run(self) -> RunStats {
        // Reject degenerate configurations here, at the API boundary,
        // with the error's message — not via a hang or index panic deep
        // inside the engine.
        if let Err(e) = self.cfg.engine.validate() {
            panic!("invalid job configuration: {e}");
        }
        let patterns = self.app.patterns();
        let induced = self.app.induced();
        let client = self.exec.client();
        let needs_sinks = self.app.needs_sinks();
        assert!(
            !needs_sinks || self.exec.supports_sinks(),
            "app '{}' needs per-embedding sinks but executor '{}' only counts",
            self.app.name(),
            self.exec.name()
        );
        let mut outcomes = Vec::with_capacity(patterns.len());
        for (i, p) in patterns.iter().enumerate() {
            let plan = {
                let plan = client.plan(p, induced);
                if self.cfg.engine.vertical_sharing {
                    plan
                } else {
                    plan.without_vertical_sharing()
                }
            };
            let ctx = PlanCtx {
                graph: self.sess.graph,
                plan: &plan,
                cfg: &self.cfg,
                pg: self.sess.pg,
                roots: &self.sess.roots,
            };
            let (stats, sinks) = if needs_sinks {
                self.exec.run_plan_with_sinks(&ctx, &|m| self.app.unit_sink(i, m))
            } else {
                (self.exec.run_plan(&ctx), Vec::new())
            };
            outcomes.push(PatternOutcome { pattern_idx: i, stats, sinks });
        }
        self.app.aggregate(outcomes)
    }
}

/// Per-unit sink of [`LabeledQuery`]: counts matches and records the
/// distinct vertices seen at each pattern position (the per-position
/// "node images" whose minimum size is the MNI support measure used by
/// frequent-subgraph mining).
pub struct SupportSink {
    pub count: u64,
    pub images: Vec<HashSet<VertexId>>,
}

impl SupportSink {
    pub fn new(k: usize) -> Self {
        SupportSink { count: 0, images: vec![HashSet::new(); k] }
    }
}

impl EmbeddingSink for SupportSink {
    fn emit(&mut self, vertices: &[VertexId]) {
        self.count += 1;
        for (i, &v) in vertices.iter().enumerate() {
            self.images[i].insert(v);
        }
    }

    fn add_count(&mut self, _n: u64) {
        unreachable!("SupportSink never bulk-counts");
    }
}

impl AppSink for SupportSink {
    fn total(&self) -> u64 {
        self.count
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Result of one query pattern of a [`LabeledQuery`] run.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub pattern_idx: usize,
    /// Total labelled embeddings matched.
    pub embeddings: u64,
    /// MNI support: minimum over pattern positions of the number of
    /// distinct graph vertices matched at that position.
    pub support: u64,
    /// Whether the pattern met the support threshold.
    pub kept: bool,
}

/// Labelled pattern queries with a support threshold — a genuinely new
/// workload that ships entirely on the [`GpmApp`] trait, with no
/// engine-internal changes: mine a set of vertex-labelled patterns,
/// compute each pattern's MNI support from per-embedding sinks, and
/// report only patterns whose support reaches `min_support` (patterns
/// below threshold report a zero count, as an FSM-style pruning pass
/// would discard them).
pub struct LabeledQuery {
    patterns: Vec<Pattern>,
    induced: Induced,
    min_support: u64,
    results: Mutex<Vec<QueryResult>>,
}

impl LabeledQuery {
    pub fn new(patterns: Vec<Pattern>, induced: Induced, min_support: u64) -> Self {
        LabeledQuery { patterns, induced, min_support, results: Mutex::new(Vec::new()) }
    }

    /// Per-pattern query results of the most recent run.
    pub fn results(&self) -> Vec<QueryResult> {
        self.results.lock().unwrap().clone()
    }

    pub fn min_support(&self) -> u64 {
        self.min_support
    }
}

impl GpmApp for LabeledQuery {
    fn name(&self) -> String {
        format!("LQ({} patterns, support>={})", self.patterns.len(), self.min_support)
    }

    fn patterns(&self) -> Vec<Pattern> {
        self.patterns.clone()
    }

    fn induced(&self) -> Induced {
        self.induced
    }

    fn needs_sinks(&self) -> bool {
        true
    }

    fn unit_sink(&self, pattern_idx: usize, _machine: usize) -> BoxSink {
        Box::new(SupportSink::new(self.patterns[pattern_idx].num_vertices()))
    }

    fn aggregate(&self, outcomes: Vec<PatternOutcome>) -> RunStats {
        let mut merged = RunStats::default();
        let mut results = Vec::with_capacity(outcomes.len());
        for o in outcomes {
            let k = self.patterns[o.pattern_idx].num_vertices();
            let mut images: Vec<HashSet<VertexId>> = vec![HashSet::new(); k];
            let mut embeddings = 0u64;
            for s in &o.sinks {
                let ss = s
                    .as_any()
                    .downcast_ref::<SupportSink>()
                    .expect("LabeledQuery units produce SupportSinks");
                embeddings += ss.count;
                for (i, img) in ss.images.iter().enumerate() {
                    images[i].extend(img.iter().copied());
                }
            }
            let support = images.iter().map(|img| img.len() as u64).min().unwrap_or(0);
            let kept = support >= self.min_support;
            let mut stats = o.stats;
            stats.counts = vec![if kept { embeddings } else { 0 }];
            merged.absorb(&stats);
            results.push(QueryResult { pattern_idx: o.pattern_idx, embeddings, support, kept });
        }
        *self.results.lock().unwrap() = results;
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::pattern::brute::count_embeddings;
    use crate::workloads::{App, EngineKind};

    #[test]
    fn session_counts_match_oracle_for_every_executor() {
        let g = gen::rmat(8, 8, 73);
        let expect = count_embeddings(&g, &Pattern::triangle(), Induced::Edge);
        let sess = MiningSession::new(&g, 4);
        for kind in [
            EngineKind::Kudu(ClientSystem::Automine),
            EngineKind::Kudu(ClientSystem::GraphPi),
            EngineKind::GThinker,
            EngineKind::MovingComp,
            EngineKind::Replicated,
            EngineKind::SingleMachine,
        ] {
            let st = sess.job(&App::Tc).executor(kind.executor()).run();
            assert_eq!(st.total_count(), expect, "{}", kind.name());
        }
    }

    #[test]
    fn session_partitions_once() {
        let g = gen::erdos_renyi(200, 700, 5);
        let sess = MiningSession::new(&g, 4);
        let total: usize = sess.owned_roots().iter().map(|r| r.len()).sum();
        assert_eq!(total, g.num_vertices());
        // Multi-pattern job over the same session state.
        let st = sess.job(&App::Mc(3)).run();
        assert_eq!(st.counts.len(), 2);
        // Another job, same shared roots (no rebuild) — still correct.
        let tc = sess.job(&App::Tc).run();
        assert_eq!(tc.total_count(), count_embeddings(&g, &Pattern::triangle(), Induced::Edge));
    }

    #[test]
    fn builder_overrides_apply() {
        let g = gen::rmat(8, 8, 17);
        let sess = MiningSession::new(&g, 4);
        let on = sess.job(&App::Cc(4)).run();
        let off = sess
            .job(&App::Cc(4))
            .vertical_sharing(false)
            .horizontal_sharing(false)
            .cache_frac(0.0)
            .run();
        assert_eq!(on.total_count(), off.total_count());
        // The ablations cost work: no-sharing does strictly more.
        assert!(off.work_units > on.work_units);
    }

    #[test]
    fn labeled_query_support_threshold() {
        let base = gen::erdos_renyi(100, 400, 211);
        let labels: Vec<u8> = (0..base.num_vertices()).map(|v| (v % 2) as u8 + 1).collect();
        let g = base.with_labels(labels);
        let queries = vec![
            Pattern::triangle().with_labels(&[1, 1, 2]),
            Pattern::chain(3).with_labels(&[2, 1, 2]),
            // A label absent from the graph: support 0, always pruned.
            Pattern::chain(3).with_labels(&[3, 1, 3]),
        ];
        let app = LabeledQuery::new(queries.clone(), Induced::Edge, 1);
        let sess = MiningSession::new(&g, 4);
        let st = sess.job(&app).run();
        let results = app.results();
        assert_eq!(results.len(), 3);
        for (i, q) in queries.iter().enumerate() {
            let expect = count_embeddings(&g, q, Induced::Edge);
            assert_eq!(results[i].embeddings, expect, "query {i}");
            assert_eq!(st.counts[i], if results[i].kept { expect } else { 0 });
        }
        assert!(!results[2].kept, "absent label must be pruned");
        assert_eq!(results[2].support, 0);

        // A high threshold prunes everything.
        let strict = LabeledQuery::new(queries, Induced::Edge, u64::MAX);
        let st2 = sess.job(&strict).run();
        assert_eq!(st2.total_count(), 0);
        assert!(strict.results().iter().all(|r| !r.kept));
    }

    #[test]
    #[should_panic(expected = "invalid job configuration")]
    fn degenerate_config_rejected_by_job_builder() {
        let g = gen::erdos_renyi(30, 60, 3);
        let mut cfg = RunConfig::with_machines(2);
        cfg.engine.mini_batch = 0;
        let _ = MiningSession::with_config(&g, cfg).job(&App::Tc).run();
    }

    #[test]
    fn scheduler_knobs_change_wall_clock_shape_not_answers() {
        let g = gen::rmat(8, 8, 91);
        let sess = MiningSession::new(&g, 2);
        let reference = sess.job(&App::Cc(4)).workers_per_machine(1).run();
        for workers in [2usize, 4] {
            let st = sess
                .job(&App::Cc(4))
                .workers_per_machine(workers)
                .max_live_chunks(8)
                .run();
            assert_eq!(st.counts, reference.counts, "workers={workers}");
            assert_eq!(st.network_bytes, reference.network_bytes);
            assert_eq!(st.virtual_time_s.to_bits(), reference.virtual_time_s.to_bits());
        }
        // A different split *decomposition* may re-slice virtual time but
        // never the mining answer.
        let split = sess.job(&App::Cc(4)).task_split(2, 4).run();
        assert_eq!(split.counts, reference.counts);
    }

    #[test]
    #[should_panic(expected = "needs per-embedding sinks")]
    fn sink_app_on_counting_executor_panics() {
        let g = gen::erdos_renyi(30, 60, 3);
        let app = LabeledQuery::new(vec![Pattern::triangle()], Induced::Edge, 1);
        let sess = MiningSession::new(&g, 2);
        let _ = sess.job(&app).executor(EngineKind::Replicated.executor()).run();
    }
}
