//! The mining-session API: Kudu's public abstraction.
//!
//! The paper's headline claim is a *well-defined abstraction* under which
//! existing single-machine GPM systems run distributed unchanged. This
//! module is that seam, split into three pieces:
//!
//! * [`MiningSession`] — owns the graph, the 1-D partitioning, and the
//!   per-machine owned-vertex lists **once**, shared by every pattern,
//!   query, and executor of the session.
//! * [`GpmApp`] — what to mine: the pattern set, the embedding semantics,
//!   an optional per-unit sink factory for per-embedding processing,
//!   optional per-level [`ExtendHooks`] (pruning, early exit), and the
//!   result aggregation. The built-in counting apps
//!   ([`crate::workloads::App`]) and the labelled-query app
//!   ([`LabeledQuery`]) are both ordinary implementations.
//! * [`Executor`] — how to mine: one compiled [`MiningProgram`] per job
//!   over the session's shared cluster state. The Kudu engine
//!   ([`KuduExec`]) executes the program *fused* — all patterns in one
//!   run, shared prefix frames explored once; the four comparator
//!   baselines interpret a program as a loop over its plans, preserving
//!   their execution models exactly.
//!
//! Jobs are built fluently:
//!
//! ```no_run
//! use kudu::graph::gen;
//! use kudu::plan::ClientSystem;
//! use kudu::session::MiningSession;
//! use kudu::workloads::App;
//!
//! let g = gen::rmat(10, 10, 42);
//! let session = MiningSession::new(&g, 8);
//! let stats = session
//!     .job(&App::Cc(4))
//!     .client(ClientSystem::Automine)
//!     .vertical_sharing(false)
//!     .run();
//! println!("4-cliques: {}", stats.total_count());
//! ```
//!
//! **Determinism.** Per pattern, everything a fused job reports —
//! counts, traffic matrices, virtual time — is bitwise identical to the
//! legacy one-plan-per-run path ([`Job::fused`]`(false)`), pinned by
//! `tests/program_equivalence.rs`; the fusion win shows up only in the
//! physical totals ([`crate::metrics::ProgramStats`]) and the wall
//! clock. Wall-clock time is measured **once per job** (the old default
//! aggregation summed per-pattern walls, overstating elapsed time once
//! patterns run fused); per-pattern virtual-time breakdowns stay in
//! [`PatternOutcome`].

use crate::baselines::{GThinker, MovingComputation, Replicated, SingleMachine};
use crate::cluster::Transport;
use crate::config::{RunConfig, StorageTier};
use crate::delta::DeltaGraph;
use crate::engine::sink::{AppSink, BoxSink, CountSink, EmbeddingSink};
use crate::engine::KuduEngine;
use crate::graph::{CompactGraph, Graph, GraphStore, VertexId};
use crate::metrics::{ProgramStats, RunStats, Traffic};
use crate::partition::PartitionedGraph;
use crate::pattern::brute::Induced;
use crate::pattern::Pattern;
use crate::plan::{ClientSystem, MiningProgram, Plan};
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

pub use crate::engine::sink::{Control, ExtendHooks};

/// Everything one pattern of a program run hands back to its app for
/// aggregation.
pub struct PatternOutcome {
    /// Index into the app's pattern list.
    pub pattern_idx: usize,
    /// Single-pattern run statistics; `counts` holds one entry (the raw
    /// embedding count reported by the executor). On the fused path
    /// these are the engine's per-pattern attribution — bitwise
    /// identical to a one-plan run — with `wall_s` zero (wall is a
    /// whole-job quantity, reported once by [`Job::run`]).
    pub stats: RunStats,
    /// The pattern's full traffic matrix (per-pattern attribution).
    pub traffic: Traffic,
    /// The finished per-unit sinks, in unit order. Empty for counting
    /// apps (executors bulk-count without materialising sinks).
    pub sinks: Vec<BoxSink>,
}

/// Outcome of executing one [`MiningProgram`]: per-pattern outcomes in
/// pattern order plus the physical totals of the execution.
pub struct ProgramOutcome {
    pub patterns: Vec<PatternOutcome>,
    pub program: ProgramStats,
}

/// A graph pattern mining application: *what* to mine and what to do with
/// each embedding. Object-safe, so apps are passed as `&dyn GpmApp`;
/// `Sync` because sink factories and hooks are invoked from concurrent
/// executor threads.
///
/// The default methods implement a plain counting app — the only code a
/// new counting workload needs is [`GpmApp::name`], [`GpmApp::patterns`],
/// and [`GpmApp::induced`]. Apps that process embeddings (support
/// counting, per-vertex statistics, …) override [`GpmApp::needs_sinks`],
/// [`GpmApp::unit_sink`], and [`GpmApp::aggregate`]; apps that need
/// per-embedding *control flow* (existence queries, top-k, pruning)
/// override [`GpmApp::hooks`]. See [`LabeledQuery`] and
/// `examples/existence.rs` for complete examples.
pub trait GpmApp: Sync {
    /// Display name (table/report headers).
    fn name(&self) -> String;

    /// The patterns this app mines, in reporting order.
    fn patterns(&self) -> Vec<Pattern>;

    /// Embedding semantics shared by all the app's patterns.
    fn induced(&self) -> Induced;

    /// True when the app must see each embedding (via [`GpmApp::unit_sink`])
    /// rather than a bulk count. Sink apps require an executor with
    /// [`Executor::supports_sinks`].
    fn needs_sinks(&self) -> bool {
        false
    }

    /// Per-level callbacks ([`ExtendHooks`]) giving the app control flow
    /// inside the enumeration: prune partial embeddings, stop at the
    /// first match, score embeddings as they appear. `None` (default)
    /// keeps the engine on its bulk-counting fast path and the bitwise
    /// determinism contract. Installing hooks compiles the app's program
    /// without cross-pattern prefix fusion (the shared root scan
    /// remains) and requires an executor with
    /// [`Executor::supports_hooks`].
    fn hooks(&self) -> Option<&dyn ExtendHooks> {
        None
    }

    /// Per-execution-unit sink factory for pattern `pattern_idx`. A unit
    /// is one scheduler task of a simulated machine (a root mini-batch or
    /// a split-off chunk — see [`crate::engine::task`]); `machine` is the
    /// unit's machine index. Only called when [`GpmApp::needs_sinks`] is
    /// true. Units are reduced in a deterministic order fixed by graph +
    /// config, never by host scheduling.
    fn unit_sink(&self, pattern_idx: usize, machine: usize) -> BoxSink {
        let _ = (pattern_idx, machine);
        Box::new(CountSink::default())
    }

    /// Fold the per-pattern outcomes (in pattern order) into the job's
    /// final statistics. The default appends counts and sums times and
    /// traffic — exactly the multi-pattern merge the counting apps need.
    /// Wall-clock is *not* the aggregate's concern: [`Job::run`]
    /// overwrites `wall_s` with the measured wall of the whole job.
    fn aggregate(&self, outcomes: Vec<PatternOutcome>) -> RunStats {
        let mut merged = RunStats::default();
        for o in &outcomes {
            merged.absorb(&o.stats);
        }
        merged
    }
}

/// Shared execution context an [`Executor`] runs one compiled
/// [`MiningProgram`] against: the session's graph, partitioning, and
/// owned-vertex lists, plus the job-resolved configuration and the
/// app's hooks.
pub struct ProgramCtx<'s, 'g> {
    pub graph: &'g Graph,
    /// The storage tier the engine reads adjacency from — the session's
    /// `Vec`-CSR graph or a job-local compressed tier
    /// ([`Job::storage`]). The baselines interpret plans over `graph`
    /// directly (their execution models predate the seam); every
    /// contract metric is bitwise tier-invariant either way.
    pub store: GraphStore<'s>,
    pub program: &'s MiningProgram,
    pub cfg: &'s RunConfig,
    /// The job's 1-D partitioning over `store`. The ownership map is a
    /// pure function of the machine count, and all its byte accounting
    /// is degree-based — identical to the session's partition-once state
    /// for every storage tier.
    pub pg: PartitionedGraph<'s>,
    /// Per-machine owned-vertex lists, unfiltered (computed once per
    /// session; executors apply root-label filters themselves).
    pub roots: &'s [Vec<VertexId>],
    /// The app's per-level callbacks, if any.
    pub hooks: Option<&'s dyn ExtendHooks>,
    /// Job-scoped external cancel flag ([`Job::cancel_flag`]): a
    /// `Release` store of `true` stops this job's execution — and only
    /// this job's — via the engine's halt plumbing. `None` for plain
    /// batch jobs, which never read any flag.
    pub cancel: Option<&'s AtomicBool>,
}

/// An execution model that can mine a compiled [`MiningProgram`] over
/// the session's shared cluster state. The Kudu engine executes programs
/// fused; the four comparator baselines interpret a program as a loop
/// over its plans (their execution models are per-plan by nature).
/// Object-safe so the harnesses select executors dynamically.
pub trait Executor: Send + Sync {
    /// Display name (table headers).
    fn name(&self) -> String;

    /// The client system whose planner compiles this executor's plans.
    /// Baselines use the GraphPi planner — best plans for everyone, so
    /// comparisons isolate the execution model.
    fn client(&self) -> ClientSystem {
        ClientSystem::GraphPi
    }

    /// Mine every pattern of the program, counting embeddings. Returns
    /// per-pattern outcomes (each with `counts = [n]`) plus the
    /// execution's physical totals.
    fn run_program(&self, ctx: &ProgramCtx<'_, '_>) -> ProgramOutcome;

    /// Whether [`Executor::run_program_with_sinks`] is available (per-
    /// embedding processing). Only the fine-grained Kudu engine exposes
    /// the paper's Algorithm-1 user function; the baselines count only.
    fn supports_sinks(&self) -> bool {
        false
    }

    /// Whether [`ProgramCtx::hooks`] are honoured. Only the Kudu engine
    /// interprets hooks; the baselines ignore per-embedding control flow.
    fn supports_hooks(&self) -> bool {
        false
    }

    /// Whether the executor reads adjacency through [`ProgramCtx::store`]
    /// (the tier seam) rather than [`ProgramCtx::graph`] directly. The
    /// baselines predate the seam and interpret plans over the `Vec`-CSR
    /// graph — fine for the static tiers (both views agree), but a
    /// [`Job::delta`] overlay exists *only* behind the seam, so delta
    /// jobs require a store-reading executor.
    fn uses_store(&self) -> bool {
        false
    }

    /// Mine every pattern of the program, feeding each embedding through
    /// per-unit sinks from `make_sink(pattern_idx, machine)`. Outcomes
    /// carry the finished sinks in unit order and `counts` = sum of sink
    /// totals.
    fn run_program_with_sinks(
        &self,
        ctx: &ProgramCtx<'_, '_>,
        make_sink: &(dyn Fn(usize, usize) -> BoxSink + Sync),
    ) -> ProgramOutcome {
        let _ = (ctx, make_sink);
        panic!(
            "executor '{}' does not support per-embedding sinks; \
             use a sink-capable executor (e.g. KuduExec) for this app",
            self.name()
        );
    }
}

/// Index-translating hook adapter: the engine reports *program-local*
/// pattern indices, apps expect *their own* pattern indices. Identical
/// for a fused whole-app program; diverging under [`Job::fused`]`(false)`,
/// where every program is single-pattern (program index always 0) —
/// exactly like the sink factory, hooks must be remapped through the
/// job's index map.
struct MappedHooks<'h> {
    inner: &'h dyn ExtendHooks,
    idx_map: &'h [usize],
}

impl ExtendHooks for MappedHooks<'_> {
    fn on_match(&self, pat: usize, vertices: &[VertexId]) -> Control {
        self.inner.on_match(self.idx_map[pat], vertices)
    }

    fn filter(&self, pat: usize, level: usize, vertices: &[VertexId]) -> Control {
        self.inner.filter(self.idx_map[pat], level, vertices)
    }
}

/// Run a program as the baselines do — one independent engine run per
/// plan (own transport, own traffic) — and package the outcomes.
/// `run_plan` returns the plan's stats plus the traffic it moved.
fn run_plans_serially(
    ctx: &ProgramCtx<'_, '_>,
    mut run_plan: impl FnMut(&Plan) -> (RunStats, Traffic),
) -> ProgramOutcome {
    // audit: wall-clock — RunStats::wall_s diagnostic, outside the
    // determinism contract.
    let wall_start = Instant::now();
    let mut patterns = Vec::with_capacity(ctx.program.num_patterns());
    let mut program = ProgramStats::default();
    for (i, plan) in ctx.program.plans().iter().enumerate() {
        // The baselines run each plan to completion (their execution
        // models predate the halt plumbing), so external cancellation
        // takes effect at plan granularity: stop before the next plan.
        // Like every halted run, the partial result is excluded from
        // the bitwise contract.
        if ctx.cancel.map_or(false, |c| c.load(Ordering::Acquire)) {
            break;
        }
        let (mut stats, traffic) = run_plan(plan);
        // Wall is a whole-job quantity, reported once (see Job::run).
        stats.wall_s = 0.0;
        program.physical_bytes += stats.network_bytes;
        program.physical_messages += stats.network_messages;
        patterns.push(PatternOutcome { pattern_idx: i, stats, traffic, sinks: Vec::new() });
    }
    program.wall_s = wall_start.elapsed().as_secs_f64();
    ProgramOutcome { patterns, program }
}

/// The Kudu engine as an [`Executor`], parameterised by the client system
/// whose planner compiles its plans. Executes programs **fused**: one
/// root scan per trie root, one scheduler and comm-fabric session for
/// all patterns.
pub struct KuduExec {
    pub client: ClientSystem,
}

impl Executor for KuduExec {
    fn name(&self) -> String {
        self.client.name().into()
    }

    fn client(&self) -> ClientSystem {
        self.client
    }

    fn run_program(&self, ctx: &ProgramCtx<'_, '_>) -> ProgramOutcome {
        let mut tr = Transport::new(ctx.pg, ctx.cfg.net);
        let mut sinks: Vec<Vec<CountSink>> = Vec::new();
        let (runs, program) = KuduEngine::run_program_cancellable(
            ctx.store,
            ctx.program,
            &ctx.cfg.engine,
            &ctx.cfg.compute,
            &mut tr,
            Some(ctx.roots),
            ctx.hooks,
            ctx.cancel,
            |_p, _m| CountSink::default(),
            &mut sinks,
        );
        let patterns = runs
            .into_iter()
            .enumerate()
            .map(|(i, pr)| {
                let mut stats = pr.stats;
                stats.counts = vec![sinks[i].iter().map(|s| s.count).sum()];
                PatternOutcome { pattern_idx: i, stats, traffic: pr.traffic, sinks: Vec::new() }
            })
            .collect();
        ProgramOutcome { patterns, program }
    }

    fn supports_sinks(&self) -> bool {
        true
    }

    fn supports_hooks(&self) -> bool {
        true
    }

    fn uses_store(&self) -> bool {
        true
    }

    fn run_program_with_sinks(
        &self,
        ctx: &ProgramCtx<'_, '_>,
        make_sink: &(dyn Fn(usize, usize) -> BoxSink + Sync),
    ) -> ProgramOutcome {
        let mut tr = Transport::new(ctx.pg, ctx.cfg.net);
        let mut sinks: Vec<Vec<BoxSink>> = Vec::new();
        let (runs, program) = KuduEngine::run_program_cancellable(
            ctx.store,
            ctx.program,
            &ctx.cfg.engine,
            &ctx.cfg.compute,
            &mut tr,
            Some(ctx.roots),
            ctx.hooks,
            ctx.cancel,
            make_sink,
            &mut sinks,
        );
        let mut sinks = sinks.into_iter();
        let patterns = runs
            .into_iter()
            .enumerate()
            .map(|(i, pr)| {
                let psinks = sinks.next().expect("one sink list per pattern");
                let mut stats = pr.stats;
                stats.counts = vec![psinks.iter().map(|s| s.total()).sum()];
                PatternOutcome { pattern_idx: i, stats, traffic: pr.traffic, sinks: psinks }
            })
            .collect();
        ProgramOutcome { patterns, program }
    }
}

/// G-thinker-like baseline as an [`Executor`] (interprets a program as a
/// loop over its plans).
pub struct GThinkerExec;

impl Executor for GThinkerExec {
    fn name(&self) -> String {
        "G-thinker".into()
    }

    fn run_program(&self, ctx: &ProgramCtx<'_, '_>) -> ProgramOutcome {
        run_plans_serially(ctx, |plan| {
            let mut tr = Transport::new(ctx.pg, ctx.cfg.net);
            let s = GThinker::run(
                ctx.graph,
                plan,
                ctx.cfg.engine.threads,
                ctx.cfg.engine.sim_threads,
                &ctx.cfg.engine.comm,
                &ctx.cfg.compute,
                &mut tr,
            );
            (s, tr.traffic)
        })
    }
}

/// Moving-computation-to-data baseline as an [`Executor`] (loops over the
/// program's plans).
pub struct MovingCompExec;

impl Executor for MovingCompExec {
    fn name(&self) -> String {
        "MovingComp".into()
    }

    fn run_program(&self, ctx: &ProgramCtx<'_, '_>) -> ProgramOutcome {
        run_plans_serially(ctx, |plan| {
            let mut tr = Transport::new(ctx.pg, ctx.cfg.net);
            let s = MovingComputation::run(
                ctx.graph,
                plan,
                ctx.cfg.engine.threads,
                &ctx.cfg.engine.comm,
                &ctx.cfg.compute,
                &mut tr,
            );
            (s, tr.traffic)
        })
    }
}

/// Replicated-graph GraphPi-like baseline as an [`Executor`] (loops over
/// the program's plans; a replicated graph moves no traffic).
pub struct ReplicatedExec;

impl Executor for ReplicatedExec {
    fn name(&self) -> String {
        "GraphPi(repl)".into()
    }

    fn run_program(&self, ctx: &ProgramCtx<'_, '_>) -> ProgramOutcome {
        run_plans_serially(ctx, |plan| {
            let s = Replicated::run(
                ctx.graph,
                plan,
                ctx.cfg.num_machines,
                ctx.cfg.engine.threads,
                ctx.cfg.engine.sim_threads,
                &ctx.cfg.compute,
            );
            (s, Traffic::new(ctx.cfg.num_machines))
        })
    }
}

/// Single-machine DFS reference as an [`Executor`] (ignores the machine
/// count; loops over the program's plans).
pub struct SingleMachineExec;

impl Executor for SingleMachineExec {
    fn name(&self) -> String {
        "single".into()
    }

    fn run_program(&self, ctx: &ProgramCtx<'_, '_>) -> ProgramOutcome {
        run_plans_serially(ctx, |plan| {
            let s = SingleMachine::run(ctx.graph, plan, &ctx.cfg.compute);
            (s, Traffic::new(ctx.cfg.num_machines))
        })
    }
}

/// A mining session: the graph, its 1-D partitioning, and the per-machine
/// owned-vertex lists, computed **once** and shared by every job. Jobs
/// borrow the session immutably, so a session serves any number of apps,
/// executors, and feature ablations without re-partitioning.
pub struct MiningSession<'g> {
    graph: &'g Graph,
    cfg: RunConfig,
    pg: PartitionedGraph<'g>,
    roots: Vec<Vec<VertexId>>,
}

impl<'g> MiningSession<'g> {
    /// Open a session over `graph` partitioned across `machines` simulated
    /// machines, with default configuration.
    pub fn new(graph: &'g Graph, machines: usize) -> Self {
        Self::with_config(graph, RunConfig::with_machines(machines))
    }

    /// Open a session with a full [`RunConfig`]. The partitioning is fixed
    /// by `cfg.num_machines` for the session's lifetime; per-job engine
    /// toggles are overridden on the job builder.
    pub fn with_config(graph: &'g Graph, cfg: RunConfig) -> Self {
        let pg = PartitionedGraph::new(graph, cfg.num_machines);
        let roots = (0..cfg.num_machines).map(|m| pg.owned_vertices(m)).collect();
        MiningSession { graph, cfg, pg, roots }
    }

    pub fn graph(&self) -> &'g Graph {
        self.graph
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    pub fn num_machines(&self) -> usize {
        self.cfg.num_machines
    }

    /// The session's shared partitioning.
    pub fn partitioned(&self) -> &PartitionedGraph<'g> {
        &self.pg
    }

    /// Per-machine owned-vertex lists (the partition-once state).
    pub fn owned_roots(&self) -> &[Vec<VertexId>] {
        &self.roots
    }

    /// Start building a job that mines `app` on this session. Defaults:
    /// the Kudu engine with the GraphPi planner, fused program execution,
    /// and the session's config.
    pub fn job<'a>(&'a self, app: &'a dyn GpmApp) -> Job<'a, 'g> {
        Job {
            sess: self,
            app,
            exec: Box::new(KuduExec { client: ClientSystem::GraphPi }),
            cfg: self.cfg.clone(),
            fused: true,
            cancel: None,
            delta: None,
        }
    }
}

/// Everything one job run reports: the app-aggregated statistics, the
/// per-pattern views (stats + traffic matrix) the aggregation consumed,
/// and the physical totals of the program execution. `Clone` so a
/// multi-tenant server ([`crate::service::MiningService`]) can hand the
/// same cached report to any number of clients.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub stats: RunStats,
    /// Per-pattern (stats, traffic matrix) in pattern order — the fused
    /// engine's per-pattern attribution, bitwise identical to legacy
    /// one-plan runs.
    pub patterns: Vec<(RunStats, Traffic)>,
    pub program: ProgramStats,
}

/// Fluent builder for one mining job: an app × an executor × config
/// overrides. Consumed by [`Job::run`].
pub struct Job<'a, 'g> {
    sess: &'a MiningSession<'g>,
    app: &'a dyn GpmApp,
    exec: Box<dyn Executor>,
    cfg: RunConfig,
    fused: bool,
    cancel: Option<&'a AtomicBool>,
    delta: Option<&'a DeltaGraph>,
}

impl<'a, 'g> Job<'a, 'g> {
    /// Mine with the Kudu engine, compiling plans with `client`'s planner.
    pub fn client(mut self, client: ClientSystem) -> Self {
        self.exec = Box::new(KuduExec { client });
        self
    }

    /// Mine with an explicit executor (baselines, custom execution models).
    pub fn executor(mut self, exec: Box<dyn Executor>) -> Self {
        self.exec = exec;
        self
    }

    /// Fused program execution (default `true`): compile all the app's
    /// plans into one [`MiningProgram`] and mine them in a single engine
    /// run — one root scan, shared prefix frames, one comm session.
    /// `false` reproduces the legacy one-plan-per-run execution exactly
    /// (separate root scans and comm sessions per pattern) — the serial
    /// reference of `tests/program_equivalence.rs` and
    /// `benches/program.rs`. Per-pattern reported metrics are bitwise
    /// identical either way.
    pub fn fused(mut self, on: bool) -> Self {
        self.fused = on;
        self
    }

    /// Toggle vertical computation sharing (paper §6.1 / Fig 13).
    pub fn vertical_sharing(mut self, on: bool) -> Self {
        self.cfg.engine.vertical_sharing = on;
        self
    }

    /// Toggle horizontal data sharing (paper §6.2 / Fig 14).
    pub fn horizontal_sharing(mut self, on: bool) -> Self {
        self.cfg.engine.horizontal_sharing = on;
        self
    }

    /// Static-cache size as a fraction of CSR bytes; `0.0` disables.
    pub fn cache_frac(mut self, frac: f64) -> Self {
        self.cfg.engine.cache_frac = frac;
        self
    }

    /// Modeled computation threads per machine (scales virtual time).
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.engine.threads = threads;
        self
    }

    /// Host threads executing the simulation (`0` = all cores). Changes
    /// wall-clock only, never the reported metrics.
    pub fn sim_threads(mut self, threads: usize) -> Self {
        self.cfg.engine.sim_threads = threads;
        self
    }

    /// Scheduler workers per simulated machine (`0` = all cores): the
    /// intra-machine work-stealing width. Like [`Job::sim_threads`], this
    /// changes wall-clock only, never the reported metrics.
    pub fn workers_per_machine(mut self, workers: usize) -> Self {
        self.cfg.engine.workers_per_machine = workers;
        self
    }

    /// Toggle the data-parallel intersection kernel tier
    /// ([`crate::exec::simd`]; default on, with runtime AVX2 detection
    /// and scalar fallback). Wall-clock only: counts, traffic matrices,
    /// and virtual time are bitwise identical for either setting — the
    /// kernels report identical [`crate::exec::Work`] by construction.
    /// `KUDU_NO_SIMD=1` in the environment force-disables regardless.
    pub fn simd(mut self, on: bool) -> Self {
        self.cfg.engine.simd = on;
        self
    }

    /// Select the graph storage tier the Kudu engine reads adjacency
    /// from ([`StorageTier`]; default [`StorageTier::Csr`]). With
    /// [`StorageTier::Compact`] the job builds a job-local compressed
    /// graph (degree-delta varint blocks, ~½ the bytes per edge — see
    /// [`crate::graph::compact`]) and mines over it. Counts, traffic
    /// matrices, and virtual time are bitwise identical for either tier;
    /// the tier surfaces only in the excluded diagnostics
    /// (`decode_s`, `bytes_per_edge`). `KUDU_NO_COMPACT=1` in the
    /// environment force-disables the compact tier regardless.
    pub fn storage(mut self, tier: StorageTier) -> Self {
        self.cfg.engine.storage = tier;
        self
    }

    /// Mine over an evolving-graph overlay ([`crate::delta::DeltaGraph`])
    /// instead of the session's static graph. The overlay's base must be
    /// the session graph (same vertex set — ingest never adds vertices),
    /// so the session's partition-once ownership map and owned-root lists
    /// apply unchanged. The delta tier takes precedence over
    /// [`Job::storage`]: the overlay *is* the storage tier for this job,
    /// and the report is bitwise identical to running the same job over
    /// [`crate::delta::DeltaGraph::materialize`] — pinned by
    /// `tests/delta_equivalence.rs`. Requires a store-reading executor
    /// ([`Executor::uses_store`]); the baselines read the static CSR
    /// directly and would silently miss overlay edges.
    pub fn delta(mut self, delta: &'a DeltaGraph) -> Self {
        self.delta = Some(delta);
        self
    }

    /// Synchronous-fetch escape hatch: `true` bypasses the
    /// message-passing comm subsystem and reads remote partitions
    /// directly through the shared cluster view (the pre-comm
    /// execution). Counts, traffic, and virtual time are bitwise
    /// identical either way — only wall-clock behaviour and the comm
    /// diagnostics (`comm_stall_s`, `peak_in_flight`, `comm_flushes`)
    /// change.
    pub fn sync_fetch(mut self, on: bool) -> Self {
        self.cfg.engine.comm.sync_fetch = on;
        self
    }

    /// In-flight request window of the comm subsystem (max outstanding
    /// logical fetches per machine; must be ≥ 1). `1` with
    /// [`Job::comm_batch_bytes`]`(0)` degenerates to synchronous
    /// blocking round trips — still real messages, just serialised.
    pub fn comm_window(mut self, max_in_flight: usize) -> Self {
        self.cfg.engine.comm.max_in_flight = max_in_flight;
        self
    }

    /// Physical aggregation threshold of the comm subsystem, in modelled
    /// request bytes (`0` = every logical request is its own envelope).
    pub fn comm_batch_bytes(mut self, bytes: u64) -> Self {
        self.cfg.engine.comm.batch_bytes = bytes;
        self
    }

    /// Task-split budgets: frames at `level < levels` hand full child
    /// chunks to the scheduler as new tasks, at most `width` per child
    /// edge per task. Changes the (deterministic) task decomposition —
    /// and with it virtual-time granularity — not the mining answer.
    pub fn task_split(mut self, levels: usize, width: usize) -> Self {
        self.cfg.engine.task_split_levels = levels;
        self.cfg.engine.task_split_width = width;
        self
    }

    /// Cap on split-off chunks queued per machine (memory bound; past
    /// it, a child task becomes the spawning worker's next task instead
    /// of queueing).
    pub fn max_live_chunks(mut self, cap: usize) -> Self {
        self.cfg.engine.max_live_chunks = cap;
        self
    }

    /// Install an external cancel flag for this job. A `Release` store
    /// of `true` from any thread stops the job — and only this job —
    /// through the engine's halt plumbing ([`Control::Halt`]): workers
    /// drain their own queues and the job reports partial results
    /// (excluded from the bitwise contract, like every halted run).
    /// Baseline executors observe the flag at plan granularity. This is
    /// the mechanism behind [`crate::service::JobHandle::cancel`].
    pub fn cancel_flag(mut self, cancel: &'a AtomicBool) -> Self {
        self.cancel = Some(cancel);
        self
    }

    /// The job's resolved configuration (session config + overrides so
    /// far). Multi-tenant servers read this to key result caches on the
    /// contract-shaping knobs.
    pub fn resolved_config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Whether the job will compile one fused program ([`Job::fused`]).
    pub fn is_fused(&self) -> bool {
        self.fused
    }

    /// The executor's display name.
    pub fn executor_name(&self) -> String {
        self.exec.name()
    }

    /// The client system whose planner compiles this job's plans.
    pub fn planner(&self) -> ClientSystem {
        self.exec.client()
    }

    /// Compile the app's patterns into the exact per-pattern [`Plan`]s
    /// this job would execute (planner + vertical-sharing toggle
    /// applied), without running anything. [`Plan::describe`] over the
    /// result is a stable textual identity for the job's program — the
    /// result-cache key material of [`crate::service::MiningService`].
    pub fn compiled_plans(&self) -> Vec<Plan> {
        let induced = self.app.induced();
        let client = self.exec.client();
        self.app
            .patterns()
            .iter()
            .map(|p| {
                let plan = client.plan(p, induced);
                if self.cfg.engine.vertical_sharing {
                    plan
                } else {
                    plan.without_vertical_sharing()
                }
            })
            .collect()
    }

    /// NUMA sockets per machine (`1` disables NUMA modelling).
    pub fn sockets(mut self, sockets: usize) -> Self {
        self.cfg.engine.sockets = sockets;
        self
    }

    /// Toggle NUMA-aware exploration (Table 7).
    pub fn numa_aware(mut self, on: bool) -> Self {
        self.cfg.engine.numa_aware = on;
        self
    }

    /// Compile one program (over `plans`, whose program indices map to
    /// app pattern indices through `idx_map`) and execute it.
    fn exec_once(
        &self,
        plans: Vec<Plan>,
        idx_map: &[usize],
        hooks: Option<&dyn ExtendHooks>,
        store: GraphStore<'_>,
    ) -> ProgramOutcome {
        // Hooked programs skip cross-pattern fusion: per-pattern control
        // flow would make shared frames diverge (the root scan still
        // merges — filtering happens on edges, not on the root chunk).
        let program = MiningProgram::compile(plans, hooks.is_none());
        // Hooks, like sinks, see app pattern indices, not program-local
        // ones.
        let mapped = hooks.map(|h| MappedHooks { inner: h, idx_map });
        let ctx = ProgramCtx {
            graph: self.sess.graph,
            store,
            program: &program,
            cfg: &self.cfg,
            // Same ownership map as the session's partition-once state
            // (a pure function of the machine count), re-wrapped around
            // the job's storage tier.
            pg: PartitionedGraph::from_store(store, self.cfg.num_machines),
            roots: &self.sess.roots,
            hooks: mapped.as_ref().map(|m| m as &dyn ExtendHooks),
            cancel: self.cancel,
        };
        let mut out = if self.app.needs_sinks() {
            self.exec.run_program_with_sinks(&ctx, &|p, m| self.app.unit_sink(idx_map[p], m))
        } else {
            self.exec.run_program(&ctx)
        };
        for po in out.patterns.iter_mut() {
            po.pattern_idx = idx_map[po.pattern_idx];
        }
        out
    }

    /// Run the job and return the full report: compile the app's plans
    /// with the executor's client planner into one fused program (or one
    /// program per pattern with [`Job::fused`]`(false)`), execute over
    /// the session's shared cluster state, and hand the outcomes to the
    /// app for aggregation. Wall-clock is measured once for the whole
    /// job; run-wide execution diagnostics are folded into the final
    /// stats.
    pub fn run_report(self) -> JobReport {
        // Reject degenerate configurations here, at the API boundary,
        // with the error's message — not via a hang or index panic deep
        // inside the engine.
        if let Err(e) = self.cfg.engine.validate() {
            panic!("invalid job configuration: {e}");
        }
        let patterns = self.app.patterns();
        let hooks = self.app.hooks();
        assert!(
            !self.app.needs_sinks() || self.exec.supports_sinks(),
            "app '{}' needs per-embedding sinks but executor '{}' only counts",
            self.app.name(),
            self.exec.name()
        );
        assert!(
            hooks.is_none() || self.exec.supports_hooks(),
            "app '{}' installs extend hooks but executor '{}' ignores them",
            self.app.name(),
            self.exec.name()
        );
        // audit: wall-clock — RunStats::wall_s diagnostic, outside the
        // determinism contract.
        let wall_start = Instant::now();
        if patterns.is_empty() {
            // Nothing to mine: aggregate over zero outcomes.
            let mut stats = self.app.aggregate(Vec::new());
            stats.wall_s = wall_start.elapsed().as_secs_f64();
            return JobReport { stats, patterns: Vec::new(), program: ProgramStats::default() };
        }
        let plans = self.compiled_plans();
        // Resolve the storage tier once per job: a compact-tier job
        // compresses the session graph here (job-local, built once) and
        // every program execution of the job reads through it. A delta
        // overlay takes precedence over the static tiers — the overlay
        // *is* this job's graph, and compressing the stale base instead
        // would silently drop the ingested edges.
        let compact: Option<CompactGraph> = match (self.delta, self.cfg.engine.storage.resolve()) {
            (None, StorageTier::Compact) => Some(CompactGraph::from_graph(self.sess.graph)),
            _ => None,
        };
        let store = match (self.delta, &compact) {
            (Some(d), _) => {
                assert!(
                    self.exec.uses_store(),
                    "job mines a delta overlay but executor '{}' reads the static CSR \
                     directly and would miss the ingested edges",
                    self.exec.name()
                );
                assert!(
                    d.num_vertices() == self.sess.graph.num_vertices(),
                    "delta overlay vertex set must match the session graph \
                     (the session's partitioning and root lists are reused)"
                );
                GraphStore::Delta(d)
            }
            (None, Some(c)) => GraphStore::Compact(c),
            (None, None) => GraphStore::Csr(self.sess.graph),
        };
        let outcome = if self.fused {
            let idx_map: Vec<usize> = (0..plans.len()).collect();
            self.exec_once(plans, &idx_map, hooks, store)
        } else {
            // Legacy one-plan-per-run execution: an independent program
            // (own root scan, own comm session) per pattern.
            let mut acc =
                ProgramOutcome { patterns: Vec::new(), program: ProgramStats::default() };
            for (i, plan) in plans.into_iter().enumerate() {
                let one = self.exec_once(vec![plan], &[i], hooks, store);
                acc.patterns.extend(one.patterns);
                acc.program.absorb(&one.program);
            }
            acc
        };
        let pattern_views: Vec<(RunStats, Traffic)> =
            outcome.patterns.iter().map(|po| (po.stats.clone(), po.traffic.clone())).collect();
        let program = outcome.program;
        let mut stats = self.app.aggregate(outcome.patterns);
        // Wall-clock once for the whole job (per-pattern virtual-time
        // breakdowns stay in the outcomes), plus the run-wide execution
        // diagnostics the fused engine reports at program level.
        stats.wall_s = wall_start.elapsed().as_secs_f64();
        stats.sched_steals += program.sched_steals;
        stats.peak_live_chunks = stats.peak_live_chunks.max(program.peak_live_chunks);
        stats.comm_stall_s += program.comm_stall_s;
        stats.peak_in_flight = stats.peak_in_flight.max(program.peak_in_flight);
        stats.comm_flushes += program.comm_flushes;
        stats.decode_s += program.decode_s;
        if stats.bytes_per_edge == 0.0 {
            stats.bytes_per_edge = program.bytes_per_edge;
        }
        JobReport { stats, patterns: pattern_views, program }
    }

    /// Run the job; see [`Job::run_report`] for the full report.
    pub fn run(self) -> RunStats {
        self.run_report().stats
    }
}

/// Per-unit sink of [`LabeledQuery`]: counts matches and records the
/// distinct vertices seen at each pattern position (the per-position
/// "node images" whose minimum size is the MNI support measure used by
/// frequent-subgraph mining).
pub struct SupportSink {
    pub count: u64,
    pub images: Vec<HashSet<VertexId>>,
}

impl SupportSink {
    pub fn new(k: usize) -> Self {
        SupportSink { count: 0, images: vec![HashSet::new(); k] }
    }
}

impl EmbeddingSink for SupportSink {
    fn emit(&mut self, vertices: &[VertexId]) {
        self.count += 1;
        for (i, &v) in vertices.iter().enumerate() {
            self.images[i].insert(v);
        }
    }

    fn add_count(&mut self, _n: u64) {
        unreachable!("SupportSink never bulk-counts");
    }
}

impl AppSink for SupportSink {
    fn total(&self) -> u64 {
        self.count
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Result of one query pattern of a [`LabeledQuery`] run.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub pattern_idx: usize,
    /// Total labelled embeddings matched.
    pub embeddings: u64,
    /// MNI support: minimum over pattern positions of the number of
    /// distinct graph vertices matched at that position.
    pub support: u64,
    /// Whether the pattern met the support threshold.
    pub kept: bool,
}

/// Labelled pattern queries with a support threshold — a workload that
/// ships entirely on the [`GpmApp`] trait, with no engine-internal
/// changes: mine a set of vertex-labelled patterns, compute each
/// pattern's MNI support from per-embedding sinks, and report only
/// patterns whose support reaches `min_support` (patterns below
/// threshold report a zero count, as an FSM-style pruning pass would
/// discard them). Multi-pattern queries run as one fused program:
/// compatible prefixes share frames, the root scan happens once.
pub struct LabeledQuery {
    patterns: Vec<Pattern>,
    induced: Induced,
    min_support: u64,
    results: Mutex<Vec<QueryResult>>,
}

impl LabeledQuery {
    pub fn new(patterns: Vec<Pattern>, induced: Induced, min_support: u64) -> Self {
        LabeledQuery { patterns, induced, min_support, results: Mutex::new(Vec::new()) }
    }

    /// Per-pattern query results of the most recent run.
    pub fn results(&self) -> Vec<QueryResult> {
        self.results.lock().unwrap().clone()
    }

    pub fn min_support(&self) -> u64 {
        self.min_support
    }
}

impl GpmApp for LabeledQuery {
    fn name(&self) -> String {
        format!("LQ({} patterns, support>={})", self.patterns.len(), self.min_support)
    }

    fn patterns(&self) -> Vec<Pattern> {
        self.patterns.clone()
    }

    fn induced(&self) -> Induced {
        self.induced
    }

    fn needs_sinks(&self) -> bool {
        true
    }

    fn unit_sink(&self, pattern_idx: usize, _machine: usize) -> BoxSink {
        Box::new(SupportSink::new(self.patterns[pattern_idx].num_vertices()))
    }

    fn aggregate(&self, outcomes: Vec<PatternOutcome>) -> RunStats {
        let mut merged = RunStats::default();
        let mut results = Vec::with_capacity(outcomes.len());
        for o in outcomes {
            let k = self.patterns[o.pattern_idx].num_vertices();
            let mut images: Vec<HashSet<VertexId>> = vec![HashSet::new(); k];
            let mut embeddings = 0u64;
            for s in &o.sinks {
                let ss = s
                    .as_any()
                    .downcast_ref::<SupportSink>()
                    .expect("LabeledQuery units produce SupportSinks");
                embeddings += ss.count;
                for (i, img) in ss.images.iter().enumerate() {
                    images[i].extend(img.iter().copied());
                }
            }
            let support = images.iter().map(|img| img.len() as u64).min().unwrap_or(0);
            let kept = support >= self.min_support;
            let mut stats = o.stats;
            stats.counts = vec![if kept { embeddings } else { 0 }];
            merged.absorb(&stats);
            results.push(QueryResult { pattern_idx: o.pattern_idx, embeddings, support, kept });
        }
        *self.results.lock().unwrap() = results;
        merged
    }
}

// Heavy under Miri (full engine runs / threads / file I/O): the Miri
// leg covers the light per-module tests and the protocol types.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::pattern::brute::count_embeddings;
    use crate::workloads::{App, EngineKind};
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn session_counts_match_oracle_for_every_executor() {
        let g = gen::rmat(8, 8, 73);
        let expect = count_embeddings(&g, &Pattern::triangle(), Induced::Edge);
        let sess = MiningSession::new(&g, 4);
        for kind in [
            EngineKind::Kudu(ClientSystem::Automine),
            EngineKind::Kudu(ClientSystem::GraphPi),
            EngineKind::GThinker,
            EngineKind::MovingComp,
            EngineKind::Replicated,
            EngineKind::SingleMachine,
        ] {
            let st = sess.job(&App::Tc).executor(kind.executor()).run();
            assert_eq!(st.total_count(), expect, "{}", kind.name());
        }
    }

    #[test]
    fn session_partitions_once() {
        let g = gen::erdos_renyi(200, 700, 5);
        let sess = MiningSession::new(&g, 4);
        let total: usize = sess.owned_roots().iter().map(|r| r.len()).sum();
        assert_eq!(total, g.num_vertices());
        // Multi-pattern job over the same session state.
        let st = sess.job(&App::Mc(3)).run();
        assert_eq!(st.counts.len(), 2);
        // Another job, same shared roots (no rebuild) — still correct.
        let tc = sess.job(&App::Tc).run();
        assert_eq!(tc.total_count(), count_embeddings(&g, &Pattern::triangle(), Induced::Edge));
    }

    #[test]
    fn builder_overrides_apply() {
        let g = gen::rmat(8, 8, 17);
        let sess = MiningSession::new(&g, 4);
        let on = sess.job(&App::Cc(4)).run();
        let off = sess
            .job(&App::Cc(4))
            .vertical_sharing(false)
            .horizontal_sharing(false)
            .cache_frac(0.0)
            .run();
        assert_eq!(on.total_count(), off.total_count());
        // The ablations cost work: no-sharing does strictly more.
        assert!(off.work_units > on.work_units);
    }

    #[test]
    fn fused_job_reports_one_root_scan_and_wall_once() {
        let g = gen::rmat(8, 8, 29);
        let sess = MiningSession::new(&g, 2);
        let fused = sess.job(&App::Mc(4)).run_report();
        let serial = sess.job(&App::Mc(4)).fused(false).run_report();
        // Same mining answers, pattern for pattern.
        assert_eq!(fused.stats.counts, serial.stats.counts);
        // One root scan instead of six.
        assert_eq!(fused.program.root_embeddings, g.num_vertices() as u64);
        assert_eq!(serial.program.root_embeddings, 6 * g.num_vertices() as u64);
        // Wall is measured once, not summed per pattern: with six fused
        // patterns it must be far below the per-pattern virtual sum
        // heuristic the old default produced (wall_s ≥ 0 and finite is
        // all we can assert portably, plus that per-pattern walls are
        // zeroed in the outcomes).
        assert!(fused.stats.wall_s > 0.0);
        assert!(fused.patterns.iter().all(|(s, _)| s.wall_s == 0.0));
        assert!(serial.patterns.iter().all(|(s, _)| s.wall_s == 0.0));
    }

    #[test]
    fn labeled_query_support_threshold() {
        let base = gen::erdos_renyi(100, 400, 211);
        let labels: Vec<u8> = (0..base.num_vertices()).map(|v| (v % 2) as u8 + 1).collect();
        let g = base.with_labels(labels);
        let queries = vec![
            Pattern::triangle().with_labels(&[1, 1, 2]),
            Pattern::chain(3).with_labels(&[2, 1, 2]),
            // A label absent from the graph: support 0, always pruned.
            Pattern::chain(3).with_labels(&[3, 1, 3]),
        ];
        let app = LabeledQuery::new(queries.clone(), Induced::Edge, 1);
        let sess = MiningSession::new(&g, 4);
        let st = sess.job(&app).run();
        let results = app.results();
        assert_eq!(results.len(), 3);
        for (i, q) in queries.iter().enumerate() {
            let expect = count_embeddings(&g, q, Induced::Edge);
            assert_eq!(results[i].embeddings, expect, "query {i}");
            assert_eq!(st.counts[i], if results[i].kept { expect } else { 0 });
        }
        assert!(!results[2].kept, "absent label must be pruned");
        assert_eq!(results[2].support, 0);

        // A high threshold prunes everything.
        let strict = LabeledQuery::new(queries, Induced::Edge, u64::MAX);
        let st2 = sess.job(&strict).run();
        assert_eq!(st2.total_count(), 0);
        assert!(strict.results().iter().all(|r| !r.kept));
    }

    #[test]
    fn storage_tier_is_invisible_in_job_reports() {
        // A compact-tier job reports the identical mining answer and the
        // identical contract metrics; only the excluded diagnostics see
        // the tier. (KUDU_NO_COMPACT would pin both jobs to CSR and void
        // the diagnostic assertions, so skip under the hatch.)
        if std::env::var_os("KUDU_NO_COMPACT").is_some() {
            return;
        }
        let g = gen::rmat(8, 8, 61);
        let sess = MiningSession::new(&g, 4);
        let a = sess.job(&App::Cc(4)).run_report();
        let b = sess.job(&App::Cc(4)).storage(crate::config::StorageTier::Compact).run_report();
        assert_eq!(a.stats.counts, b.stats.counts);
        assert_eq!(a.stats.network_bytes, b.stats.network_bytes);
        assert_eq!(a.stats.network_messages, b.stats.network_messages);
        assert_eq!(a.stats.work_units, b.stats.work_units);
        assert_eq!(a.stats.virtual_time_s.to_bits(), b.stats.virtual_time_s.to_bits());
        assert_eq!(a.stats.cache_hits, b.stats.cache_hits);
        assert_eq!(a.stats.sched_tasks, b.stats.sched_tasks);
        // Diagnostics: the compact tier charges decode and packs edges
        // tighter; CSR charges nothing. (Under KUDU_COMPACT_GRAPH the
        // default job is compact too, so only the compact side asserts.)
        assert!(b.stats.decode_s > 0.0);
        assert!(b.stats.bytes_per_edge > 0.0);
        if std::env::var_os("KUDU_COMPACT_GRAPH").is_none() {
            assert_eq!(a.stats.decode_s, 0.0);
            assert!(b.stats.bytes_per_edge < a.stats.bytes_per_edge);
        }
    }

    #[test]
    #[should_panic(expected = "invalid job configuration")]
    fn degenerate_config_rejected_by_job_builder() {
        let g = gen::erdos_renyi(30, 60, 3);
        let mut cfg = RunConfig::with_machines(2);
        cfg.engine.mini_batch = 0;
        let _ = MiningSession::with_config(&g, cfg).job(&App::Tc).run();
    }

    #[test]
    fn scheduler_knobs_change_wall_clock_shape_not_answers() {
        let g = gen::rmat(8, 8, 91);
        let sess = MiningSession::new(&g, 2);
        let reference = sess.job(&App::Cc(4)).workers_per_machine(1).run();
        for workers in [2usize, 4] {
            let st = sess
                .job(&App::Cc(4))
                .workers_per_machine(workers)
                .max_live_chunks(8)
                .run();
            assert_eq!(st.counts, reference.counts, "workers={workers}");
            assert_eq!(st.network_bytes, reference.network_bytes);
            assert_eq!(st.virtual_time_s.to_bits(), reference.virtual_time_s.to_bits());
        }
        // A different split *decomposition* may re-slice virtual time but
        // never the mining answer.
        let split = sess.job(&App::Cc(4)).task_split(2, 4).run();
        assert_eq!(split.counts, reference.counts);
    }

    #[test]
    #[should_panic(expected = "needs per-embedding sinks")]
    fn sink_app_on_counting_executor_panics() {
        let g = gen::erdos_renyi(30, 60, 3);
        let app = LabeledQuery::new(vec![Pattern::triangle()], Induced::Edge, 1);
        let sess = MiningSession::new(&g, 2);
        let _ = sess.job(&app).executor(EngineKind::Replicated.executor()).run();
    }

    /// Minimal hook app: count triangles but prune every subtree rooted
    /// at an odd second vertex — per-embedding control flow through the
    /// public API only.
    struct OddPrune {
        seen: AtomicU64,
    }

    impl ExtendHooks for OddPrune {
        fn filter(&self, _pat: usize, _level: usize, vertices: &[VertexId]) -> Control {
            if vertices[1] % 2 == 1 {
                Control::Prune
            } else {
                Control::Continue
            }
        }

        fn on_match(&self, _pat: usize, _vertices: &[VertexId]) -> Control {
            self.seen.fetch_add(1, Ordering::Relaxed);
            Control::Continue
        }
    }

    impl GpmApp for OddPrune {
        fn name(&self) -> String {
            "odd-prune".into()
        }

        fn patterns(&self) -> Vec<Pattern> {
            vec![Pattern::triangle()]
        }

        fn induced(&self) -> Induced {
            Induced::Edge
        }

        fn hooks(&self) -> Option<&dyn ExtendHooks> {
            Some(self)
        }
    }

    #[test]
    fn hooks_prune_subtrees_and_see_matches() {
        let g = gen::erdos_renyi(80, 320, 97);
        let sess = MiningSession::new(&g, 3);
        let app = OddPrune { seen: AtomicU64::new(0) };
        let st = sess.job(&app).run();
        let full = sess.job(&App::Tc).run();
        // Pruning removed work, deterministically.
        assert!(st.total_count() < full.total_count());
        assert_eq!(st.total_count(), app.seen.load(Ordering::Relaxed));
        // Bitwise-deterministic even with hooks, as long as nothing
        // halts: same job, same answer.
        let app2 = OddPrune { seen: AtomicU64::new(0) };
        let st2 = sess.job(&app2).run();
        assert_eq!(st.counts, st2.counts);
        assert_eq!(st.work_units, st2.work_units);
    }

    #[test]
    #[should_panic(expected = "installs extend hooks")]
    fn hook_app_on_baseline_executor_panics() {
        let g = gen::erdos_renyi(30, 60, 3);
        let app = OddPrune { seen: AtomicU64::new(0) };
        let sess = MiningSession::new(&g, 2);
        let _ = sess.job(&app).executor(EngineKind::GThinker.executor()).run();
    }
}
