//! Tiny flag parser (the image vendors only the `xla` crate closure, so
//! CLI parsing is in-tree). Supports `--flag value`, `--flag=value`, and
//! boolean `--flag`, plus the spec parsers that map CLI strings onto the
//! mining-session API ([`parse_app`], [`parse_engine`], [`parse_pattern`],
//! [`parse_dataset`]).

use crate::graph::gen;
use crate::pattern::Pattern;
use crate::plan::ClientSystem;
use crate::workloads::{App, EngineKind};
use std::collections::HashMap;

/// Dataset abbreviation → stand-in dataset.
pub fn parse_dataset(name: &str) -> Option<gen::Dataset> {
    Some(match name {
        "mc" => gen::Dataset::Mico,
        "pt" => gen::Dataset::Patents,
        "lj" => gen::Dataset::LiveJournal,
        "uk" => gen::Dataset::Uk,
        "tw" => gen::Dataset::Twitter,
        "fr" => gen::Dataset::Friendster,
        "rm" => gen::Dataset::RmatLarge,
        "yh" => gen::Dataset::Yahoo,
        _ => return None,
    })
}

/// App spec (`tc`, `K-mc`, `K-cc`) → [`App`].
pub fn parse_app(s: &str) -> App {
    let s = s.to_lowercase();
    if s == "tc" {
        return App::Tc;
    }
    if let Some(k) = s.strip_suffix("-mc") {
        return App::Mc(k.parse().expect("bad k in k-mc"));
    }
    if let Some(k) = s.strip_suffix("-cc") {
        return App::Cc(k.parse().expect("bad k in k-cc"));
    }
    panic!("unknown app '{s}' (expected tc, K-mc, or K-cc)");
}

/// Engine spec → [`EngineKind`] (resolve to an executor with
/// [`EngineKind::executor`]).
pub fn parse_engine(s: &str) -> EngineKind {
    match s.to_lowercase().as_str() {
        "k-automine" | "automine" => EngineKind::Kudu(ClientSystem::Automine),
        "k-graphpi" | "graphpi" => EngineKind::Kudu(ClientSystem::GraphPi),
        "gthinker" | "g-thinker" => EngineKind::GThinker,
        "movingcomp" | "arabesque" => EngineKind::MovingComp,
        "replicated" => EngineKind::Replicated,
        "single" => EngineKind::SingleMachine,
        other => panic!("unknown engine '{other}'"),
    }
}

/// Job spec for the `serve` subcommand (`APP[@ENGINE]`, e.g. `tc`,
/// `4-mc@k-automine`) → ([`App`], [`EngineKind`]). The engine defaults
/// to the Kudu engine with the GraphPi planner, like
/// [`crate::service::JobOptions`].
pub fn parse_job_spec(s: &str) -> (App, EngineKind) {
    match s.split_once('@') {
        Some((app, engine)) => (parse_app(app), parse_engine(engine)),
        None => (parse_app(s), EngineKind::Kudu(ClientSystem::GraphPi)),
    }
}

/// Pattern spec (`triangle`, `clique-K`, `chain-K`, `cycle-K`, `star-K`,
/// `diamond`, `tailed-triangle`) → [`Pattern`].
pub fn parse_pattern(s: &str) -> Pattern {
    let s = s.to_lowercase();
    if s == "triangle" {
        return Pattern::triangle();
    }
    if s == "diamond" {
        return Pattern::diamond();
    }
    if s == "tailed-triangle" {
        return Pattern::tailed_triangle();
    }
    for (prefix, f) in [
        ("clique-", Pattern::clique as fn(usize) -> Pattern),
        ("chain-", Pattern::chain),
        ("cycle-", Pattern::cycle),
        ("star-", Pattern::star),
    ] {
        if let Some(k) = s.strip_prefix(prefix) {
            return f(k.parse().expect("bad pattern size"));
        }
    }
    panic!("unknown pattern '{s}'");
}

/// Parsed arguments: positional values plus `--key value` flags.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (without argv[0]).
    pub fn parse(raw: impl Iterator<Item = String>) -> Self {
        let mut out = Args::default();
        let raw: Vec<String> = raw.collect();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.flags.insert(flag.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Parsed flag with default.
    pub fn get_as<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Boolean switch (present, `=true`, or `true` value).
    pub fn has(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["run", "--graph", "mc", "--machines=4", "--no-cache"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("graph", "x"), "mc");
        assert_eq!(a.get_as::<usize>("machines", 1), 4);
        assert!(a.has("no-cache"));
        assert!(!a.has("no-hds"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get("engine", "k-graphpi"), "k-graphpi");
        assert_eq!(a.get_as::<usize>("threads", 1), 1);
    }

    #[test]
    fn bool_then_positional() {
        let a = parse(&["--verbose", "stats"]);
        // "stats" follows a flag without value and does not start with
        // "--": it is consumed as the flag's value by design; callers put
        // the subcommand first.
        assert_eq!(a.get("verbose", ""), "stats");
    }

    #[test]
    fn spec_parsers() {
        assert_eq!(parse_app("tc"), App::Tc);
        assert_eq!(parse_app("4-MC"), App::Mc(4));
        assert_eq!(parse_app("5-cc"), App::Cc(5));
        assert_eq!(parse_engine("k-graphpi"), EngineKind::Kudu(ClientSystem::GraphPi));
        assert_eq!(parse_engine("single"), EngineKind::SingleMachine);
        assert_eq!(parse_job_spec("tc"), (App::Tc, EngineKind::Kudu(ClientSystem::GraphPi)));
        assert_eq!(parse_job_spec("4-mc@gthinker"), (App::Mc(4), EngineKind::GThinker));
        assert_eq!(parse_pattern("clique-4").num_vertices(), 4);
        assert!(parse_dataset("lj").is_some());
        assert!(parse_dataset("nope").is_none());
    }
}
