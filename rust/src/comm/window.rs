//! The in-flight request window and server stop flag: the two lock-free
//! protocols of the comm fabric, extracted into small types so they can
//! be model-checked in isolation.
//!
//! `tests/loom_models.rs` drives these exact types through every
//! interleaving of requester and server steps with the
//! [`crate::modelcheck`] explorer, proving the properties the fabric
//! relies on: the window never holds more than `max_in_flight`
//! reservations, a full window cannot deadlock (whenever it is full the
//! server has servable work, because requesters flush before waiting),
//! and the stop flag's release store pairs with the server loop's
//! acquire load so shutdown is observed after all requester writes.
//!
//! **Memory-ordering contract** (registered in `tools/audit/atomics.toml`
//! under `count` / `peak` / `stop`, `comm/window.rs`):
//!
//! * `count` — the reservation CAS uses `AcqRel` on success and the
//!   completion `fetch_sub` uses `AcqRel`, making the window slot itself
//!   a synchronization point between the server that freed a slot and
//!   the requester that reuses it — conservative and independent of the
//!   reply-slot `OnceLock` (which already synchronizes the response
//!   payload). The pre-CAS load and the retry loads are `Relaxed`: a
//!   stale value only causes a retry or one more spin, never a bound
//!   violation (the CAS re-validates against the latest value).
//! * `stop` — classic `Release` store / `Acquire` load handshake:
//!   everything written before [`StopFlag::signal`] is visible to a
//!   server that observes it and exits.
//! * `peak` — diagnostic high-water mark, `Relaxed`, outside the
//!   determinism contract.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Bounded pool of outstanding non-blocking requests: at most
/// `max_in_flight` reservations held at once.
pub struct InFlightWindow {
    /// Logical fetches reserved and not yet completed.
    count: AtomicUsize,
    limit: usize,
    /// Diagnostic high-water mark of `count`.
    peak: AtomicUsize,
}

impl InFlightWindow {
    /// A window of `limit` slots (clamped to at least 1 — a zero window
    /// would turn every reservation into an unbounded spin).
    pub fn new(limit: usize) -> Self {
        InFlightWindow {
            count: AtomicUsize::new(0),
            limit: limit.max(1),
            peak: AtomicUsize::new(0),
        }
    }

    /// Try to reserve one window slot. `true` holds a slot until
    /// [`InFlightWindow::complete`]; `false` means the window is full
    /// right now — the caller decides how to wait (the fabric flushes
    /// its outboxes once, then spin-yields, so the server always has the
    /// servable work that will free a slot). Never blocks.
    pub fn try_reserve(&self) -> bool {
        let mut cur = self.count.load(Ordering::Relaxed);
        loop {
            if cur >= self.limit {
                return false;
            }
            match self.count.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak.fetch_max(cur + 1, Ordering::Relaxed);
                    return true;
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Complete one reserved request, freeing its slot (the server calls
    /// this after filling the reply slot).
    pub fn complete(&self) {
        let prev = self.count.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "complete without a matching reserve");
    }

    /// Currently reserved slots (diagnostic / model-check observation).
    pub fn outstanding(&self) -> usize {
        self.count.load(Ordering::Relaxed)
    }

    /// The window size.
    pub fn limit(&self) -> usize {
        self.limit
    }

    /// Diagnostic high-water mark of reserved slots.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }
}

/// Release/acquire shutdown handshake for the comm server threads.
pub struct StopFlag {
    stop: AtomicBool,
}

impl StopFlag {
    pub fn new() -> Self {
        StopFlag { stop: AtomicBool::new(false) }
    }

    /// Signal shutdown. The `Release` store pairs with the `Acquire`
    /// load in [`StopFlag::is_signaled`]: everything the signaler wrote
    /// beforehand is visible to an observer that sees `true`.
    pub fn signal(&self) {
        self.stop.store(true, Ordering::Release);
    }

    /// Has shutdown been signaled? (`Acquire` — see [`StopFlag::signal`].)
    pub fn is_signaled(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }
}

impl Default for StopFlag {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_reserves_up_to_limit() {
        let w = InFlightWindow::new(2);
        assert!(w.try_reserve());
        assert!(w.try_reserve());
        assert!(!w.try_reserve());
        w.complete();
        assert!(w.try_reserve());
        assert_eq!(w.peak(), 2);
        assert_eq!(w.outstanding(), 2);
    }

    #[test]
    fn zero_window_clamps_to_one() {
        let w = InFlightWindow::new(0);
        assert_eq!(w.limit(), 1);
        assert!(w.try_reserve());
        assert!(!w.try_reserve());
    }

    #[test]
    fn stop_flag_round_trip() {
        let s = StopFlag::new();
        assert!(!s.is_signaled());
        s.signal();
        assert!(s.is_signaled());
    }
}
