//! The wire protocol of the comm subsystem: typed messages that actually
//! cross between machine threads.
//!
//! The protocol is deliberately pure request/response (Arabesque-style
//! coordination-free messaging): a [`FetchRequest`] names a batch of
//! vertices, a [`FetchResponse`] carries their materialised adjacency
//! payloads, and nothing else ever flows back. Responses are therefore a
//! pure function of graph + request — the property the determinism
//! contract of `tests/comm_equivalence.rs` rests on. [`ShipEmbeddings`]
//! is the one-way embedding-shipping message the moving-computation
//! (G-thinker/Arabesque-family) baselines use for their shuffles.
//!
//! Physical transport: logical messages are aggregated into
//! [`WireBatch`] envelopes (the comm layer's MPI-style aggregation; see
//! [`super::CommFabric`]) and delivered into the destination machine's
//! mailbox.

use crate::graph::VertexId;
use std::sync::{Arc, OnceLock};

/// Reply slot of one logical fetch: filled exactly once by the owning
/// machine's comm server, polled by the requester (and by the scheduler,
/// to decide when a parked task is runnable again).
pub type ResponseSlot = Arc<OnceLock<FetchResponse>>;

/// One logical fetch: a batch of vertex ids (all owned by the destination
/// machine) whose adjacency lists the requester needs.
pub struct FetchRequest {
    /// The requested vertices, in request order.
    pub vertices: Vec<VertexId>,
    /// Where the serving machine deposits the response.
    pub reply: ResponseSlot,
}

/// Materialised adjacency payloads answering one [`FetchRequest`]:
/// `payload(i)` is the edge list of `request.vertices[i]`, copied out of
/// the owner's partition exactly as it would arrive off the wire.
pub struct FetchResponse {
    /// CSR-style offsets into `data`; `offsets.len() == vertices + 1`.
    pub offsets: Vec<u32>,
    /// Concatenated adjacency payloads.
    pub data: Vec<VertexId>,
}

impl FetchResponse {
    /// Number of per-vertex payloads carried.
    #[inline]
    pub fn num_payloads(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// The adjacency payload of the i-th requested vertex.
    #[inline]
    pub fn payload(&self, i: usize) -> &[VertexId] {
        &self.data[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }
}

/// One-way embedding-shipping message (the moving-computation baseline's
/// shuffle): `count` partial embeddings of `level` matched vertices each,
/// plus `extra_bytes` of piggybacked edge-list payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShipEmbeddings {
    pub count: u64,
    pub level: usize,
    pub extra_bytes: u64,
}

/// A logical message on the wire.
pub enum Message {
    Fetch(FetchRequest),
    Ship(ShipEmbeddings),
}

/// One physical envelope: the flushed aggregate of logical messages from
/// one machine to one destination mailbox.
pub struct WireBatch {
    /// Sending machine (the fetches' requester).
    pub from: usize,
    pub msgs: Vec<Message>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_payload_slicing() {
        let r = FetchResponse { offsets: vec![0, 3, 3, 5], data: vec![1, 2, 3, 9, 9] };
        assert_eq!(r.num_payloads(), 3);
        assert_eq!(r.payload(0), &[1, 2, 3]);
        assert_eq!(r.payload(1), &[] as &[VertexId]);
        assert_eq!(r.payload(2), &[9, 9]);
    }

    #[test]
    fn protocol_types_cross_threads() {
        fn assert_send<T: Send>() {}
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send::<Message>();
        assert_send::<WireBatch>();
        assert_send_sync::<ResponseSlot>();
    }
}
