//! The message-passing communication subsystem: asynchronous remote
//! fetches with batching, an in-flight window, and per-machine mailboxes.
//!
//! Before this module existed, a "remote fetch" was a synchronous read of
//! the shared [`crate::cluster::ClusterView`] — overlap between
//! communication and computation was only *imputed* by the virtual
//! timeline, never exercised. The comm subsystem makes the messages real:
//!
//! * **Wire protocol** ([`proto`]) — typed [`FetchRequest`] /
//!   [`FetchResponse`] pairs (plus [`ShipEmbeddings`] for the BSP-style
//!   baselines), pure request/response so a response is a function of
//!   graph + request and nothing else.
//! * **[`CommFabric`]** — one port per machine: an incoming mailbox, a
//!   per-destination outbox that aggregates logical requests into
//!   size-bounded [`WireBatch`] envelopes (MPI-style aggregation, bounded
//!   by [`CommConfig::batch_bytes`]), and an in-flight request window
//!   ([`CommConfig::max_in_flight`]) modelling a bounded pool of
//!   outstanding non-blocking requests.
//! * **Per-machine comm server** ([`CommFabric::run_server`]) — each
//!   machine's requests are served from a thread owned by that machine
//!   (the engine spawns one per simulated machine): it pops envelopes,
//!   materialises adjacency payloads from the machine's own partition,
//!   and fills each request's reply slot. Requesters never read another
//!   machine's partition directly.
//!
//! **What stays deterministic.** Traffic accounting and virtual-time math
//! are charged at *issue* time, per logical request, with the wire-cost
//! formulas below — the one place the cost of a message is defined
//! ([`fetch_cost`], [`ship_bytes`]; [`crate::cluster`] delegates here).
//! Physical aggregation, window stalls, and message timing affect only
//! wall-clock behaviour and the comm diagnostics (`comm_stall_s`,
//! `peak_in_flight`, `comm_flushes` in [`crate::metrics::RunStats`]).
//! Counts, traffic matrices, and virtual time are bitwise identical to
//! the synchronous path for any window/batch setting — pinned by
//! `tests/comm_equivalence.rs`. The synchronous escape hatch
//! ([`CommConfig::sync_fetch`], env `KUDU_SYNC_FETCH`) bypasses messaging
//! entirely and reproduces the pre-comm execution exactly; the degenerate
//! `max_in_flight = 1, batch_bytes = 0` setting keeps the messages but
//! serialises them into blocking round trips.

pub mod proto;
pub mod window;

pub use proto::{FetchRequest, FetchResponse, Message, ResponseSlot, ShipEmbeddings, WireBatch};
pub use window::{InFlightWindow, StopFlag};

use crate::graph::{GraphStore, VertexId};
use crate::metrics::NetModel;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Wire-format overhead per vertex request/response (vertex id + length
/// header), matching a compact MPI encoding.
pub const PER_VERTEX_HEADER_BYTES: u64 = 8;
/// Fixed per-message envelope.
pub const PER_MESSAGE_BYTES: u64 = 64;

/// Wire cost of one batched fetch of `vertices`: (request bytes, payload
/// bytes, transfer time). Pure — no accounting, no side effects. This is
/// the single definition of the fetch cost formula; the transport layer
/// ([`crate::cluster::ClusterView::fetch_cost`]) delegates here.
///
/// Degree-only: adjacency always crosses the simulated wire in its
/// decoded 4-bytes-per-id form regardless of the storage tier (the paper
/// ships edge lists, not compressed pages), so traffic matrices and
/// transfer times are bitwise identical across tiers by construction.
#[inline]
pub fn fetch_cost(graph: GraphStore<'_>, net: &NetModel, vertices: &[VertexId]) -> (u64, u64, f64) {
    let payload: u64 = vertices
        .iter()
        .map(|&v| graph.degree(v) as u64 * 4 + PER_VERTEX_HEADER_BYTES)
        .sum::<u64>()
        + PER_MESSAGE_BYTES;
    // Request message (vertex ids) + response (edge lists).
    let request: u64 = vertices.len() as u64 * 4 + PER_MESSAGE_BYTES;
    let time = net.transfer_time(request) + net.transfer_time(payload);
    (request, payload, time)
}

/// Wire bytes of one embedding-shipping message: `count` embeddings of
/// `level` vertices each, plus piggybacked edge-list payload. The single
/// definition of the shipping cost formula
/// ([`crate::cluster::ClusterView::ship_embeddings`] delegates here).
#[inline]
pub fn ship_bytes(count: u64, level: usize, extra_bytes: u64) -> u64 {
    count * (level as u64 * 4) + extra_bytes + PER_MESSAGE_BYTES
}

/// Knobs of the comm subsystem (part of
/// [`crate::config::EngineConfig`], validated by
/// [`crate::config::EngineConfig::validate`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CommConfig {
    /// Maximum logical fetch requests a machine may have outstanding
    /// (issued, response not yet received). Models a bounded pool of
    /// non-blocking MPI requests; must be ≥ 1. `1` (with `batch_bytes =
    /// 0`) degenerates to synchronous blocking round trips.
    pub max_in_flight: usize,
    /// Outbox aggregation threshold in modelled request bytes: logical
    /// requests to one destination are buffered into a single physical
    /// envelope until the buffer reaches this size (it is always flushed
    /// before the requester waits or a task parks). `0` sends every
    /// logical request as its own envelope.
    pub batch_bytes: u64,
    /// Escape hatch: bypass the message-passing subsystem and read remote
    /// partitions synchronously through the shared `ClusterView` (the
    /// pre-comm execution, reproduced exactly). Counts, traffic, and
    /// virtual time are bitwise identical either way; only wall-clock
    /// behaviour and the comm diagnostics differ. Env-overridable default
    /// via `KUDU_SYNC_FETCH=1` (the CI determinism matrix pins it).
    pub sync_fetch: bool,
}

impl Default for CommConfig {
    fn default() -> Self {
        CommConfig {
            max_in_flight: env_usize("KUDU_MAX_IN_FLIGHT", 16),
            batch_bytes: 4096,
            sync_fetch: env_flag("KUDU_SYNC_FETCH"),
        }
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false)
}

/// Outgoing aggregation buffer toward one destination.
struct Outbox {
    msgs: Vec<Message>,
    /// Modelled request bytes buffered (the `batch_bytes` gauge).
    bytes: u64,
}

/// One machine's side of the fabric: incoming mailbox, outgoing
/// aggregation buffers, window state, and diagnostics.
struct MachinePort {
    /// Incoming physical envelopes, served by this machine's comm thread.
    inbox: Mutex<VecDeque<WireBatch>>,
    /// Per-destination outgoing aggregation buffers.
    out: Vec<Mutex<Outbox>>,
    /// Logical fetches issued by this machine and not yet answered — the
    /// bounded reservation pool, extracted into its own model-checked
    /// type (see [`window`]).
    window: InFlightWindow,
    // --- diagnostics (wall-clock artefacts, outside the determinism
    // contract like `RunStats::wall_s`) ---
    flushes: AtomicU64,
    stall_ns: AtomicU64,
}

/// Aggregated comm diagnostics of one run (see
/// [`crate::metrics::RunStats`] for field semantics).
#[derive(Clone, Copy, Debug, Default)]
pub struct CommDiagnostics {
    /// Wall-clock seconds requesters spent stalled on the window or on
    /// pending responses, summed over machines.
    pub stall_s: f64,
    /// Peak outstanding logical fetches on any machine.
    pub peak_in_flight: u64,
    /// Physical envelopes sent (fetch flushes + ship messages).
    pub flushes: u64,
}

/// Stops a fabric's comm servers when dropped. Hosts place one inside
/// the thread scope that spawned the servers, so the scope's implicit
/// join always completes — even when a worker panic unwinds past the
/// normal shutdown call.
pub struct ShutdownGuard<'f>(pub Option<&'f CommFabric>);

impl Drop for ShutdownGuard<'_> {
    fn drop(&mut self) {
        if let Some(f) = self.0 {
            f.shutdown();
        }
    }
}

/// The message-passing fabric of one run: per-machine ports plus the
/// shared shutdown flag for the comm server threads.
///
/// Mailboxes are bounded *by construction* rather than by blocking
/// senders: a machine can have at most `max_in_flight` logical fetches
/// outstanding, so a mailbox never holds more than
/// `(num_machines - 1) × max_in_flight` unserved fetch requests (each at
/// most one envelope), and the BSP ship path enqueues at most one
/// envelope per machine pair per superstep, drained at the next barrier.
/// HUGE-style bounded-memory comm without a send-side block that could
/// deadlock the window.
pub struct CommFabric {
    cfg: CommConfig,
    ports: Vec<MachinePort>,
    stop: StopFlag,
}

impl CommFabric {
    pub fn new(num_machines: usize, mut cfg: CommConfig) -> Self {
        // Defensive clamp: a zero window would turn every issue into an
        // unbounded spin. `EngineConfig::validate` reports ZeroInFlight
        // as a config error on the engine/session path; direct fabric
        // users (baselines, tests) and a stray `KUDU_MAX_IN_FLIGHT=0`
        // env get the degenerate-but-live window of 1 instead of a hang.
        cfg.max_in_flight = cfg.max_in_flight.max(1);
        let ports = (0..num_machines)
            .map(|_| MachinePort {
                inbox: Mutex::new(VecDeque::new()),
                out: (0..num_machines)
                    .map(|_| Mutex::new(Outbox { msgs: Vec::new(), bytes: 0 }))
                    .collect(),
                window: InFlightWindow::new(cfg.max_in_flight),
                flushes: AtomicU64::new(0),
                stall_ns: AtomicU64::new(0),
            })
            .collect();
        CommFabric { cfg, ports, stop: StopFlag::new() }
    }

    pub fn num_machines(&self) -> usize {
        self.ports.len()
    }

    pub fn config(&self) -> &CommConfig {
        &self.cfg
    }

    /// Issue one logical fetch from `machine` to `owner`: reserve a slot
    /// in the machine's in-flight window (flushing and stalling while the
    /// window is full), buffer the request in the outbox toward `owner`,
    /// and auto-flush once the buffer reaches `batch_bytes`. Returns the
    /// reply slot the owner's comm server will fill. Does **no** traffic
    /// accounting — the caller charges the wire cost at issue time, which
    /// is what keeps metrics bitwise identical to the synchronous path.
    pub fn issue_fetch(
        &self,
        machine: usize,
        owner: usize,
        vertices: Vec<VertexId>,
    ) -> ResponseSlot {
        debug_assert_ne!(machine, owner, "local reads never go through the fabric");
        let port = &self.ports[machine];
        // Reserve a window slot; while the window is full, flush so the
        // outstanding requests are servable, then spin-yield. The
        // reservation CAS itself lives in [`InFlightWindow`], where it
        // is model-checked (`tests/loom_models.rs`).
        let mut flushed = false;
        let mut stall_t0: Option<Instant> = None;
        while !port.window.try_reserve() {
            if !flushed {
                self.flush(machine);
                flushed = true;
            }
            if stall_t0.is_none() {
                // audit: wall-clock — comm_stall_s diagnostic, outside
                // the determinism contract.
                stall_t0 = Some(Instant::now());
            }
            std::thread::yield_now();
        }
        if let Some(t0) = stall_t0 {
            port.stall_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }

        let slot: ResponseSlot = Arc::new(OnceLock::new());
        let request_bytes = vertices.len() as u64 * 4 + PER_MESSAGE_BYTES;
        let should_flush = {
            let mut out = port.out[owner].lock().unwrap();
            out.msgs.push(Message::Fetch(FetchRequest { vertices, reply: slot.clone() }));
            out.bytes += request_bytes;
            out.bytes >= self.cfg.batch_bytes
        };
        if should_flush {
            self.flush_to(machine, owner);
        }
        slot
    }

    /// Flush the outbox from `machine` toward `dest` as one physical
    /// envelope (no-op when empty).
    fn flush_to(&self, machine: usize, dest: usize) {
        let msgs = {
            let mut out = self.ports[machine].out[dest].lock().unwrap();
            if out.msgs.is_empty() {
                return;
            }
            out.bytes = 0;
            std::mem::take(&mut out.msgs)
        };
        self.ports[machine].flushes.fetch_add(1, Ordering::Relaxed);
        self.ports[dest].inbox.lock().unwrap().push_back(WireBatch { from: machine, msgs });
    }

    /// Flush every outbox of `machine`. Requesters call this before any
    /// wait (and tasks before parking), so every issued request is
    /// servable before anyone depends on its response — the liveness
    /// invariant of the batching layer.
    pub fn flush(&self, machine: usize) {
        for dest in 0..self.ports.len() {
            if dest != machine {
                self.flush_to(machine, dest);
            }
        }
    }

    /// Serve everything currently queued for `machine`: materialise
    /// adjacency payloads from the shared CSR (this machine's partition —
    /// requests are only ever routed to their owner) and fill each reply
    /// slot. Ship messages are one-way and must be drained with
    /// [`CommFabric::recv_ships`] instead. Returns the number of logical
    /// fetches served.
    pub fn serve(&self, machine: usize, graph: GraphStore<'_>) -> usize {
        let mut served = 0usize;
        let mut scratch: Vec<VertexId> = Vec::new();
        loop {
            let batch = { self.ports[machine].inbox.lock().unwrap().pop_front() };
            let Some(batch) = batch else { break };
            for msg in batch.msgs {
                match msg {
                    Message::Fetch(req) => {
                        let mut offsets = Vec::with_capacity(req.vertices.len() + 1);
                        let mut data = Vec::new();
                        offsets.push(0u32);
                        for &v in &req.vertices {
                            let nb = graph.neighbors_into(v, &mut scratch);
                            data.extend_from_slice(nb);
                            offsets.push(data.len() as u32);
                        }
                        let dup = req.reply.set(FetchResponse { offsets, data }).is_err();
                        debug_assert!(!dup, "a request is served exactly once");
                        // Response received ⇒ the requester's window slot
                        // frees (completion of a non-blocking request).
                        self.ports[batch.from].window.complete();
                        served += 1;
                    }
                    Message::Ship(_) => {
                        unreachable!("ship messages are drained via recv_ships")
                    }
                }
            }
        }
        served
    }

    /// Body of `machine`'s dedicated comm server thread: serve incoming
    /// fetches until [`CommFabric::shutdown`], backing off to short
    /// sleeps when idle.
    pub fn run_server(&self, machine: usize, graph: GraphStore<'_>) {
        let mut idle = 0u32;
        while !self.stop.is_signaled() {
            if self.serve(machine, graph) > 0 {
                idle = 0;
                continue;
            }
            idle += 1;
            if idle < 64 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
        }
    }

    /// Signal the comm server threads to exit (called after the worker
    /// pool has joined — no requester is waiting by then).
    pub fn shutdown(&self) {
        self.stop.signal();
    }

    /// Block until `slot` is filled, recording the stall on `machine`'s
    /// port. The response is guaranteed to arrive: every issued request
    /// was flushed before this wait (see [`CommFabric::flush`]) and the
    /// owner's server thread runs until shutdown.
    pub fn wait<'s>(&self, machine: usize, slot: &'s ResponseSlot) -> &'s FetchResponse {
        if let Some(r) = slot.get() {
            return r;
        }
        // audit: wall-clock — comm_stall_s diagnostic, outside the
        // determinism contract.
        let t0 = Instant::now();
        loop {
            if let Some(r) = slot.get() {
                self.ports[machine]
                    .stall_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                return r;
            }
            std::thread::yield_now();
        }
    }

    /// Send one embedding-shipping message (its own envelope — shuffles
    /// are already aggregated per destination by the caller). Like
    /// fetches, the wire cost is accounted by the caller at send time.
    pub fn send_ship(&self, machine: usize, dest: usize, ship: ShipEmbeddings) {
        self.ports[machine].flushes.fetch_add(1, Ordering::Relaxed);
        self.ports[dest]
            .inbox
            .lock()
            .unwrap()
            .push_back(WireBatch { from: machine, msgs: vec![Message::Ship(ship)] });
    }

    /// Drain the embedding-shipping messages queued for `machine` (the
    /// BSP receive phase of the moving-computation baseline).
    pub fn recv_ships(&self, machine: usize) -> Vec<ShipEmbeddings> {
        let mut ships = Vec::new();
        loop {
            let batch = { self.ports[machine].inbox.lock().unwrap().pop_front() };
            let Some(batch) = batch else { break };
            for msg in batch.msgs {
                match msg {
                    Message::Ship(s) => ships.push(s),
                    Message::Fetch(_) => {
                        unreachable!("fetches are served by the comm server, not recv_ships")
                    }
                }
            }
        }
        ships
    }

    /// Sum the per-port diagnostics of the run.
    pub fn diagnostics(&self) -> CommDiagnostics {
        let mut stall_ns = 0u64;
        let mut peak = 0usize;
        let mut flushes = 0u64;
        for p in &self.ports {
            stall_ns += p.stall_ns.load(Ordering::Relaxed);
            peak = peak.max(p.window.peak());
            flushes += p.flushes.load(Ordering::Relaxed);
        }
        CommDiagnostics {
            stall_s: stall_ns as f64 / 1e9,
            peak_in_flight: peak as u64,
            flushes,
        }
    }
}

// Heavy under Miri (full engine runs / threads / file I/O): the Miri
// leg covers the light per-module tests and the protocol types.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use crate::cluster::Transport;
    use crate::graph::gen;
    use crate::partition::PartitionedGraph;

    fn async_cfg(max_in_flight: usize, batch_bytes: u64) -> CommConfig {
        CommConfig { max_in_flight, batch_bytes, sync_fetch: false }
    }

    /// Satellite: the wire-cost formula lives in exactly one place — pin
    /// the current byte numbers and the transport layer's delegation.
    #[test]
    fn wire_cost_formula_pinned() {
        // Degrees: v0 → 3, v1 → 1, v2 → 2, v3 → 2.
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (0, 2), (0, 3), (2, 3)]);
        let net = NetModel::default();
        let (req, pay, time) = fetch_cost(GraphStore::Csr(&g), &net, &[0, 1]);
        // Request: 2 ids × 4B + 64B envelope.
        assert_eq!(req, 2 * 4 + PER_MESSAGE_BYTES);
        // Payload: (3 + 1) adjacency ids × 4B + 2 × 8B headers + 64B.
        assert_eq!(pay, 4 * 4 + 2 * PER_VERTEX_HEADER_BYTES + PER_MESSAGE_BYTES);
        assert_eq!(time.to_bits(), (net.transfer_time(req) + net.transfer_time(pay)).to_bits());
        // The transport layer reports the same numbers through its
        // delegating wrappers.
        let pg = PartitionedGraph::new(&g, 2);
        let t = Transport::new(pg, net);
        assert_eq!(t.view().fetch_cost(&[0, 1]), (req, pay, time));
        // Ship formula: count·level·4 + extra + envelope.
        assert_eq!(ship_bytes(10, 3, 100), 10 * 12 + 100 + PER_MESSAGE_BYTES);
        assert_eq!(ship_bytes(0, 5, 0), PER_MESSAGE_BYTES);
    }

    #[test]
    fn fetch_round_trip_delivers_adjacency() {
        let g = gen::erdos_renyi(60, 200, 7);
        let fabric = CommFabric::new(2, async_cfg(4, 0));
        let verts: Vec<VertexId> = vec![1, 5, 9];
        let slot = fabric.issue_fetch(0, 1, verts.clone());
        // batch_bytes = 0 ⇒ the request flushed immediately; the owner's
        // serve call answers it.
        assert!(slot.get().is_none());
        assert_eq!(fabric.serve(1, GraphStore::Csr(&g)), 1);
        let resp = fabric.wait(0, &slot);
        assert_eq!(resp.num_payloads(), verts.len());
        for (i, &v) in verts.iter().enumerate() {
            assert_eq!(resp.payload(i), g.neighbors(v), "vertex {v}");
        }
        // The window slot freed on service.
        assert_eq!(fabric.ports[0].window.outstanding(), 0);
    }

    #[test]
    fn batching_aggregates_until_flush() {
        let g = gen::erdos_renyi(40, 120, 11);
        // Huge threshold: nothing flushes on its own.
        let fabric = CommFabric::new(2, async_cfg(8, u64::MAX));
        let s1 = fabric.issue_fetch(0, 1, vec![1]);
        let s2 = fabric.issue_fetch(0, 1, vec![3]);
        let s3 = fabric.issue_fetch(0, 1, vec![5]);
        // Buffered: the owner sees nothing yet.
        assert_eq!(fabric.serve(1, GraphStore::Csr(&g)), 0);
        assert_eq!(fabric.diagnostics().flushes, 0);
        fabric.flush(0);
        // One physical envelope carried all three logical requests.
        assert_eq!(fabric.diagnostics().flushes, 1);
        assert_eq!(fabric.serve(1, GraphStore::Csr(&g)), 3);
        for s in [&s1, &s2, &s3] {
            assert!(s.get().is_some());
        }
    }

    #[test]
    fn degenerate_batch_bytes_sends_every_request_alone() {
        let g = gen::erdos_renyi(40, 120, 13);
        let fabric = CommFabric::new(3, async_cfg(8, 0));
        fabric.issue_fetch(0, 1, vec![1]);
        fabric.issue_fetch(0, 2, vec![2]);
        fabric.issue_fetch(0, 1, vec![3]);
        assert_eq!(fabric.diagnostics().flushes, 3);
        assert_eq!(fabric.serve(1, GraphStore::Csr(&g)) + fabric.serve(2, GraphStore::Csr(&g)), 3);
    }

    #[test]
    fn window_bounds_outstanding_requests() {
        let g = gen::erdos_renyi(200, 800, 17);
        let window = 3usize;
        let fabric = CommFabric::new(2, async_cfg(window, 0));
        std::thread::scope(|scope| {
            let f = &fabric;
            let gr = GraphStore::Csr(&g);
            let server = scope.spawn(move || f.run_server(1, gr));
            let mut slots = Vec::new();
            for i in 0..50u32 {
                slots.push(fabric.issue_fetch(0, 1, vec![i % 100]));
            }
            fabric.flush(0);
            for s in &slots {
                fabric.wait(0, s);
            }
            fabric.shutdown();
            server.join().unwrap();
        });
        let d = fabric.diagnostics();
        assert!(d.peak_in_flight as usize <= window, "peak {} > window {window}", d.peak_in_flight);
        assert!(d.flushes >= 50, "every request flushed");
    }

    #[test]
    fn zero_window_is_clamped_to_one() {
        // A zero window would spin forever in issue_fetch; the fabric
        // defends itself (the engine/session path additionally reports
        // ConfigError::ZeroInFlight at validation).
        let fabric = CommFabric::new(2, async_cfg(0, 0));
        assert_eq!(fabric.config().max_in_flight, 1);
        let g = gen::erdos_renyi(20, 40, 5);
        let slot = fabric.issue_fetch(0, 1, vec![3]);
        assert_eq!(fabric.serve(1, GraphStore::Csr(&g)), 1);
        assert!(slot.get().is_some());
    }

    #[test]
    fn ship_messages_round_trip() {
        let fabric = CommFabric::new(2, async_cfg(1, 0));
        let ship = ShipEmbeddings { count: 42, level: 3, extra_bytes: 99 };
        fabric.send_ship(0, 1, ship);
        fabric.send_ship(0, 1, ShipEmbeddings { count: 1, level: 2, extra_bytes: 0 });
        let got = fabric.recv_ships(1);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], ship);
        assert_eq!(fabric.recv_ships(1).len(), 0);
        assert_eq!(fabric.recv_ships(0).len(), 0);
    }

    #[test]
    fn shutdown_stops_servers() {
        let g = gen::erdos_renyi(20, 40, 3);
        let fabric = CommFabric::new(2, async_cfg(2, 0));
        std::thread::scope(|scope| {
            let f = &fabric;
            let gr = GraphStore::Csr(&g);
            let handles: Vec<_> =
                (0..2).map(|m| scope.spawn(move || f.run_server(m, gr))).collect();
            let slot = fabric.issue_fetch(0, 1, vec![0]);
            fabric.wait(0, &slot);
            fabric.shutdown();
            for h in handles {
                h.join().unwrap();
            }
        });
    }
}
