//! Simulated distributed cluster: machines, an accounted transport, and a
//! virtual communication timeline.
//!
//! Substitution for the paper's 8-node MPI/InfiniBand testbed (DESIGN.md
//! §1). All graph partitions live in one address space; *policy* is
//! unchanged — a machine may touch a remote vertex's adjacency list only
//! by issuing a fetch through the transport layer, which copies the data
//! (remote edge lists are materialised into the requester's chunk arena,
//! exactly as they would arrive off the wire) and records bytes/messages.
//! Batched fetches get one latency charge, modelling MPI message
//! aggregation.
//!
//! The transport is split so the simulated machines can execute on
//! concurrent host threads (one thread per machine):
//!
//! * [`ClusterView`] — the shared, read-only side: partitioned graph +
//!   network cost model. `Copy`, freely shareable across threads.
//! * [`TrafficLedger`] — the mutable side, one per machine executor:
//!   a private traffic matrix merged (associatively, u64 sums) into the
//!   run's [`Transport`] after the fork-join, so the reduction order can
//!   never change reported numbers.
//! * [`Transport`] — owns the merged [`Traffic`] for a run and doubles as
//!   a single-ledger convenience for serial callers and tests.

use crate::comm;
use crate::graph::{GraphStore, VertexId};
use crate::metrics::{NetModel, Traffic};
use crate::partition::PartitionedGraph;

// The wire-cost formulas (and their constants) live in the comm layer —
// the one place a message's bytes are defined; re-exported here for the
// transport-facing callers that predate the comm subsystem.
pub use crate::comm::{PER_MESSAGE_BYTES, PER_VERTEX_HEADER_BYTES};

/// Shared, read-only view of the simulated cluster: the partitioned graph
/// plus the network cost model. Nothing here is mutable, so a copy can be
/// handed to every machine-executor thread.
#[derive(Clone, Copy)]
pub struct ClusterView<'g> {
    pg: PartitionedGraph<'g>,
    net: NetModel,
}

impl<'g> ClusterView<'g> {
    pub fn new(pg: PartitionedGraph<'g>, net: NetModel) -> Self {
        ClusterView { pg, net }
    }

    /// The shared graph behind the storage-tier seam. Adjacency access
    /// goes through [`GraphStore::neighbors_into`] with a caller-owned
    /// scratch; degree/label/size queries never decode.
    #[inline]
    pub fn graph(&self) -> GraphStore<'g> {
        self.pg.store
    }

    #[inline]
    pub fn partitioned(&self) -> &PartitionedGraph<'g> {
        &self.pg
    }

    #[inline]
    pub fn net(&self) -> &NetModel {
        &self.net
    }

    #[inline]
    pub fn num_machines(&self) -> usize {
        self.pg.map.num_machines()
    }

    /// Wire cost of one batched fetch of `vertices`: (request bytes,
    /// payload bytes, transfer time). Pure — no accounting. Delegates to
    /// [`comm::fetch_cost`], the single definition of the formula.
    #[inline]
    pub fn fetch_cost(&self, vertices: &[VertexId]) -> (u64, u64, f64) {
        comm::fetch_cost(self.pg.store, &self.net, vertices)
    }

    /// Fetch the edge lists of `vertices` (all owned by `from`) into
    /// `requester`'s memory as one batched message, accounting the bytes
    /// on `ledger`. Returns the total bytes and the modelled transfer
    /// time. The caller copies the adjacency data into its arena — the
    /// copy is the "receive".
    pub fn fetch_batch(
        &self,
        ledger: &mut TrafficLedger,
        requester: usize,
        from: usize,
        vertices: &[VertexId],
    ) -> (u64, f64) {
        if vertices.is_empty() {
            return (0, 0.0);
        }
        debug_assert!(vertices.iter().all(|&v| self.pg.owner(v) == from));
        if requester == from {
            // Local: no traffic, no modelled latency.
            return (0, 0.0);
        }
        let (request, payload, time) = self.fetch_cost(vertices);
        ledger.record(requester, from, request);
        ledger.record(from, requester, payload);
        (request + payload, time)
    }

    /// Ship a batch of partial embeddings (for the moving-computation
    /// baseline): `count` embeddings of `level` vertices each, plus
    /// piggybacked edge-list bytes.
    pub fn ship_embeddings(
        &self,
        ledger: &mut TrafficLedger,
        from: usize,
        to: usize,
        count: u64,
        level: usize,
        extra_bytes: u64,
    ) -> (u64, f64) {
        if from == to || count == 0 {
            return (0, 0.0);
        }
        let bytes = comm::ship_bytes(count, level, extra_bytes);
        ledger.record(from, to, bytes);
        (bytes, self.net.transfer_time(bytes))
    }
}

/// Per-executor traffic ledger: a private traffic matrix owned by one
/// simulated machine's host thread. Ledgers are merged into the run's
/// [`Transport`] after the fork-join; merging sums u64 counters, so it is
/// associative and commutative and the reduction order cannot change any
/// reported number.
#[derive(Clone, Debug)]
pub struct TrafficLedger {
    traffic: Traffic,
}

impl TrafficLedger {
    pub fn new(num_machines: usize) -> Self {
        TrafficLedger { traffic: Traffic::new(num_machines) }
    }

    #[inline]
    pub fn record(&mut self, from: usize, to: usize, bytes: u64) {
        self.traffic.record(from, to, bytes);
    }

    #[inline]
    pub fn traffic(&self) -> &Traffic {
        &self.traffic
    }

    /// Fold another ledger's matrix into this one (u64 sums — associative
    /// and commutative, so merge order never changes a reported number).
    /// Scheduler workers merge their private ledgers machine-side before
    /// the machine ledger reaches the run's [`Transport`].
    pub fn merge(&mut self, other: &TrafficLedger) {
        self.traffic.merge(other.traffic());
    }
}

/// The accounted transport between simulated machines: the shared
/// [`ClusterView`] plus the merged per-run [`Traffic`].
pub struct Transport<'g> {
    view: ClusterView<'g>,
    pub traffic: Traffic,
}

impl<'g> Transport<'g> {
    pub fn new(pg: PartitionedGraph<'g>, net: NetModel) -> Self {
        let n = pg.map.num_machines();
        Transport { view: ClusterView::new(pg, net), traffic: Traffic::new(n) }
    }

    /// The shared read-only side, copyable across executor threads.
    #[inline]
    pub fn view(&self) -> ClusterView<'g> {
        self.view
    }

    /// Fold one executor's ledger into the run totals.
    pub fn merge_ledger(&mut self, ledger: &TrafficLedger) {
        self.traffic.merge(ledger.traffic());
    }

    #[inline]
    pub fn graph(&self) -> GraphStore<'g> {
        self.view.graph()
    }

    #[inline]
    pub fn partitioned(&self) -> &PartitionedGraph<'g> {
        self.view.partitioned()
    }

    #[inline]
    pub fn num_machines(&self) -> usize {
        self.view.num_machines()
    }

    /// Single-ledger convenience: [`ClusterView::fetch_batch`] accounted
    /// directly on the run totals (serial callers and tests). Delegates
    /// through a throwaway ledger so the cost math lives in one place.
    pub fn fetch_batch(&mut self, requester: usize, from: usize, vertices: &[VertexId]) -> (u64, f64) {
        let mut ledger = TrafficLedger::new(self.num_machines());
        let out = self.view.fetch_batch(&mut ledger, requester, from, vertices);
        self.traffic.merge(ledger.traffic());
        out
    }

    /// Single-ledger convenience mirroring [`ClusterView::ship_embeddings`].
    pub fn ship_embeddings(
        &mut self,
        from: usize,
        to: usize,
        count: u64,
        level: usize,
        extra_bytes: u64,
    ) -> (u64, f64) {
        let mut ledger = TrafficLedger::new(self.num_machines());
        let out = self.view.ship_embeddings(&mut ledger, from, to, count, level, extra_bytes);
        self.traffic.merge(ledger.traffic());
        out
    }
}

/// A per-machine virtual timeline implementing the circulant pipeline of
/// paper §5.3: communication of batch b+1 overlaps computation of batch b,
/// and communication is not stalled by computation.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    /// When the communication channel becomes free.
    comm_free: f64,
    /// When the compute resource becomes free.
    compute_free: f64,
    /// Compute time actually spent (busy).
    compute_busy: f64,
    /// Comm time spent.
    comm_busy: f64,
}

impl Timeline {
    /// Post a data transfer on the communication channel; returns the
    /// arrival (gate) time. The channel free-runs ahead of compute — the
    /// paper's non-strict pipelining ("once the data required by batch-i
    /// has been fetched, the system immediately starts the communication
    /// of batch-(i+1)").
    pub fn post_comm(&mut self, comm_s: f64) -> f64 {
        self.comm_free += comm_s;
        self.comm_busy += comm_s;
        self.comm_free
    }

    /// Post compute gated on a data arrival time.
    pub fn post_compute(&mut self, gate: f64, compute_s: f64) {
        let start = self.compute_free.max(gate);
        self.compute_free = start + compute_s;
        self.compute_busy += compute_s;
    }

    /// Process one circulant batch: data transfer `comm_s`, then compute
    /// `compute_s` once the data has arrived.
    pub fn batch(&mut self, comm_s: f64, compute_s: f64) {
        let gate = self.post_comm(comm_s);
        self.post_compute(gate, compute_s);
    }

    /// Add compute-only work (local batches, post-processing).
    pub fn compute(&mut self, compute_s: f64) {
        self.compute_free += compute_s;
        self.compute_busy += compute_s;
    }

    /// Finish time of this machine.
    pub fn finish(&self) -> f64 {
        self.compute_free.max(self.comm_free)
    }

    /// Communication time left exposed on the critical path: total time
    /// minus compute-busy time (what the paper plots in Fig 14/16).
    pub fn exposed_comm(&self) -> f64 {
        (self.finish() - self.compute_busy).max(0.0)
    }

    pub fn compute_busy(&self) -> f64 {
        self.compute_busy
    }

    pub fn comm_busy(&self) -> f64 {
        self.comm_busy
    }
}

// Heavy under Miri (full engine runs / threads / file I/O): the Miri
// leg covers the light per-module tests and the protocol types.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn local_fetch_is_free() {
        let g = gen::erdos_renyi(100, 300, 1);
        let pg = PartitionedGraph::new(&g, 4);
        let mut t = Transport::new(pg, NetModel::default());
        let owned = t.partitioned().owned_vertices(2);
        let (bytes, time) = t.fetch_batch(2, 2, &owned[..3.min(owned.len())]);
        assert_eq!(bytes, 0);
        assert_eq!(time, 0.0);
        assert_eq!(t.traffic.total_bytes(), 0);
    }

    #[test]
    fn remote_fetch_accounts_bytes() {
        let g = gen::erdos_renyi(100, 300, 1);
        let pg = PartitionedGraph::new(&g, 4);
        let mut t = Transport::new(pg, NetModel::default());
        let owned = t.partitioned().owned_vertices(1);
        let vs = &owned[..2.min(owned.len())];
        let deg: u64 = vs.iter().map(|&v| t.graph().degree(v) as u64).sum();
        let (bytes, time) = t.fetch_batch(0, 1, vs);
        assert!(bytes >= deg * 4);
        assert!(time > 0.0);
        assert_eq!(t.traffic.total_bytes(), bytes);
        assert_eq!(t.traffic.total_messages(), 2); // request + response
    }

    #[test]
    fn timeline_overlaps_comm_and_compute() {
        // Three batches: comm 1s each, compute 2s each. Pipelined: total
        // = 1 (first comm) + 3·2 = 7, not (1+2)·3 = 9.
        let mut tl = Timeline::default();
        for _ in 0..3 {
            tl.batch(1.0, 2.0);
        }
        assert!((tl.finish() - 7.0).abs() < 1e-9, "finish {}", tl.finish());
        assert!((tl.exposed_comm() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn timeline_comm_bound() {
        // Comm dominates: compute hides entirely inside transfers.
        let mut tl = Timeline::default();
        for _ in 0..4 {
            tl.batch(3.0, 1.0);
        }
        assert!((tl.finish() - 13.0).abs() < 1e-9); // 4·3 + trailing 1
        assert!((tl.exposed_comm() - 9.0).abs() < 1e-9);
    }

    #[test]
    fn ship_embeddings_accounting() {
        let g = gen::erdos_renyi(50, 100, 2);
        let pg = PartitionedGraph::new(&g, 2);
        let mut t = Transport::new(pg, NetModel::default());
        let (b, s) = t.ship_embeddings(0, 1, 10, 3, 100);
        assert_eq!(b, 10 * 12 + 100 + PER_MESSAGE_BYTES);
        assert!(s > 0.0);
        let (b0, s0) = t.ship_embeddings(0, 0, 10, 3, 100);
        assert_eq!((b0, s0), (0, 0.0));
    }

    #[test]
    fn ledger_fetch_matches_transport_fetch() {
        // The split path (view + per-machine ledger, merged after) must
        // account byte-for-byte like the single-ledger convenience path.
        let g = gen::erdos_renyi(200, 700, 3);
        let pg = PartitionedGraph::new(&g, 4);
        let mut direct = Transport::new(pg, NetModel::default());
        let view = direct.view();
        let owned1 = view.partitioned().owned_vertices(1);
        let owned2 = view.partitioned().owned_vertices(2);
        let vs1 = &owned1[..4.min(owned1.len())];
        let vs2 = &owned2[..3.min(owned2.len())];
        let (db1, dt1) = direct.fetch_batch(0, 1, vs1);
        let (db2, dt2) = direct.fetch_batch(3, 2, vs2);

        let pg2 = PartitionedGraph::new(&g, 4);
        let mut split = Transport::new(pg2, NetModel::default());
        let sview = split.view();
        let mut ledger_a = TrafficLedger::new(4);
        let mut ledger_b = TrafficLedger::new(4);
        let (sb1, st1) = sview.fetch_batch(&mut ledger_a, 0, 1, vs1);
        let (sb2, st2) = sview.fetch_batch(&mut ledger_b, 3, 2, vs2);
        // Merge in the opposite order: u64 sums are order-proof.
        split.merge_ledger(&ledger_b);
        split.merge_ledger(&ledger_a);

        assert_eq!((db1, db2), (sb1, sb2));
        assert_eq!((dt1, dt2), (st1, st2));
        assert_eq!(direct.traffic.total_bytes(), split.traffic.total_bytes());
        assert_eq!(direct.traffic.total_messages(), split.traffic.total_messages());
    }

    #[test]
    fn view_is_shareable_across_threads() {
        let g = gen::erdos_renyi(100, 300, 5);
        let pg = PartitionedGraph::new(&g, 4);
        let t = Transport::new(pg, NetModel::default());
        let view = t.view();
        let totals: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|m| {
                    s.spawn(move || {
                        let mut ledger = TrafficLedger::new(4);
                        let owned = view.partitioned().owned_vertices((m + 1) % 4);
                        let vs = &owned[..2.min(owned.len())];
                        view.fetch_batch(&mut ledger, m, (m + 1) % 4, vs);
                        ledger.traffic().total_bytes()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(totals.iter().all(|&b| b > 0));
    }
}
