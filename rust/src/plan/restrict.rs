//! Symmetry-breaking restriction generation (GraphZero/GraphPi style).
//!
//! A pattern with |Aut| > 1 would be counted |Aut| times by naive
//! enumeration. Restrictions are order constraints `v_a < v_b` over the
//! matched vertex ids such that, for each subgraph, exactly one of its
//! |Aut| labelled matches survives.
//!
//! We use the orbit–stabiliser construction: repeatedly take the smallest
//! vertex `u` moved by the remaining automorphism group, emit `u < w` for
//! every other vertex `w` in `u`'s orbit, then descend to the stabiliser
//! of `u`. Correctness is checked empirically against the brute-force
//! oracle in this module's tests and the crate's proptests.

use crate::pattern::Pattern;

/// Generate a complete set of symmetry-breaking restrictions `(a, b)`
/// (meaning: require `v_a < v_b`) for `p` in its current vertex order.
pub fn symmetry_restrictions(p: &Pattern) -> Vec<(usize, usize)> {
    let mut group = p.automorphisms();
    let mut restrictions = Vec::new();
    let n = p.num_vertices();
    loop {
        if group.len() <= 1 {
            break;
        }
        // Smallest vertex moved by any remaining automorphism.
        let u = (0..n)
            .find(|&v| group.iter().any(|g| g[v] != v))
            .expect("non-trivial group moves something");
        // Orbit of u under the remaining group.
        let mut orbit: Vec<usize> = group.iter().map(|g| g[u]).collect();
        orbit.sort_unstable();
        orbit.dedup();
        for &w in &orbit {
            if w != u {
                restrictions.push((u, w));
            }
        }
        // Stabiliser of u.
        group.retain(|g| g[u] == u);
    }
    restrictions
}

/// The product of orbit sizes — must equal |Aut(p)| for the restriction
/// set to cancel the overcount exactly (orbit–stabiliser theorem).
pub fn restriction_factor(p: &Pattern) -> u64 {
    let mut group = p.automorphisms();
    let n = p.num_vertices();
    let mut factor = 1u64;
    while group.len() > 1 {
        let u = (0..n).find(|&v| group.iter().any(|g| g[v] != v)).unwrap();
        let mut orbit: Vec<usize> = group.iter().map(|g| g[u]).collect();
        orbit.sort_unstable();
        orbit.dedup();
        factor *= orbit.len() as u64;
        group.retain(|g| g[u] == u);
    }
    factor
}

// Heavy under Miri (full engine runs / threads / file I/O): the Miri
// leg covers the light per-module tests and the protocol types.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::pattern::brute::{count_embeddings, count_labelled, Induced};
    use crate::pattern::motifs::all_motifs;

    /// Count labelled matches that satisfy all restrictions — must equal
    /// the subgraph (unlabelled) count.
    fn restricted_count(
        g: &crate::graph::Graph,
        p: &Pattern,
        restr: &[(usize, usize)],
        induced: Induced,
    ) -> u64 {
        // Brute force over all labelled matches, filtering by restrictions.
        // Reuses the oracle by enumerating assignments directly.
        let mut count = 0u64;
        let k = p.num_vertices();
        let mut assignment = vec![u32::MAX; k];
        fn rec(
            g: &crate::graph::Graph,
            p: &Pattern,
            restr: &[(usize, usize)],
            induced: Induced,
            a: &mut Vec<u32>,
            lvl: usize,
            count: &mut u64,
        ) {
            let k = p.num_vertices();
            if lvl == k {
                *count += 1;
                return;
            }
            'v: for v in 0..g.num_vertices() as u32 {
                for j in 0..lvl {
                    if a[j] == v {
                        continue 'v;
                    }
                    let has = g.has_edge(a[j], v);
                    if p.has_edge(j, lvl) {
                        if !has {
                            continue 'v;
                        }
                    } else if induced == Induced::Vertex && has {
                        continue 'v;
                    }
                }
                for &(x, y) in restr {
                    if x < lvl && y == lvl && a[x] >= v {
                        continue 'v;
                    }
                    if y < lvl && x == lvl && v >= a[y] {
                        continue 'v;
                    }
                }
                a[lvl] = v;
                rec(g, p, restr, induced, a, lvl + 1, count);
                a[lvl] = u32::MAX;
            }
        }
        rec(g, p, restr, induced, &mut assignment, 0, &mut count);
        count
    }

    #[test]
    fn factor_equals_aut_order() {
        for p in [
            Pattern::triangle(),
            Pattern::clique(4),
            Pattern::clique(5),
            Pattern::chain(3),
            Pattern::chain(4),
            Pattern::cycle(4),
            Pattern::cycle(5),
            Pattern::star(4),
            Pattern::diamond(),
            Pattern::tailed_triangle(),
        ] {
            assert_eq!(
                restriction_factor(&p),
                p.automorphisms().len() as u64,
                "orbit product must equal |Aut| for {p:?}"
            );
        }
    }

    #[test]
    fn restrictions_exactly_cancel_overcount() {
        let g = gen::erdos_renyi(40, 140, 17);
        for p in all_motifs(3).into_iter().chain(all_motifs(4)) {
            let restr = symmetry_restrictions(&p);
            for induced in [Induced::Edge, Induced::Vertex] {
                let expect = count_embeddings(&g, &p, induced);
                let got = restricted_count(&g, &p, &restr, induced);
                assert_eq!(got, expect, "pattern {p:?} induced {induced:?}");
            }
        }
    }

    #[test]
    fn asymmetric_pattern_needs_no_restrictions() {
        // Tailed triangle + one more pendant making it asymmetric:
        // 0-1,0-2,1-2,2-3,3-4 has a reflection? 0<->1 swap is an
        // automorphism, so pick a truly asymmetric one: add 0-3.
        let p = Pattern::new(5, &[(0, 1), (0, 2), (1, 2), (2, 3), (3, 4), (0, 3)]);
        if p.automorphisms().len() == 1 {
            assert!(symmetry_restrictions(&p).is_empty());
        }
    }

    #[test]
    fn labelled_ratio_sanity() {
        let g = gen::erdos_renyi(30, 90, 3);
        let p = Pattern::triangle();
        let labelled = count_labelled(&g, &p, Induced::Edge);
        let unlabelled = count_embeddings(&g, &p, Induced::Edge);
        assert_eq!(labelled, unlabelled * 6);
    }
}
