//! Mining programs: all of an app's plans compiled into one shared
//! prefix trie (the multi-pattern face of the extendable-embedding
//! abstraction).
//!
//! A [`Plan`] describes one pattern's enumeration as a chain of per-level
//! steps. A [`MiningProgram`] merges the chains of *every* pattern an app
//! mines into a trie: plans whose first `k` levels are **compatible**
//! (identical intersection sources, identical symmetry-breaking
//! restrictions, identical label/exclusion constraints, and identical
//! storage/active-vertex flags — the *restriction compatibility check*)
//! share one trie node per level up to `k`, and diverge into per-pattern
//! continuations below. The engine then explores each shared node's
//! frames **once**: a 4-motif-count program does one root scan instead of
//! six, and a remote edge list fetched for a shared frame crosses the
//! wire once however many patterns extend through it (HUGE and
//! DwarvesGraph report the same cross-pattern wins).
//!
//! **Per-pattern attribution.** Sharing is an execution optimisation,
//! never an accounting one: the engine charges every shared frame's
//! work, traffic, and virtual time to *each* pattern alive at the node,
//! with the same formulas in the same order as a single-pattern run. Per
//! pattern, the fused program therefore reports counts, traffic matrices
//! (cell for cell), and virtual time bitwise identical to running that
//! pattern's plan alone — pinned by `tests/program_equivalence.rs`. What
//! the fusion changes is the *physical* totals (one root scan, deduped
//! wire traffic), reported separately in
//! [`crate::metrics::ProgramStats`].
//!
//! A node may be **terminal** for one pattern (its last matching level)
//! and interior for another — a 3-chain query rides along inside a
//! 4-chain query's program. Terminal patterns never materialise
//! embeddings at their last level (the engine bulk-processes the
//! candidate window), so a node's `store`/`needs_adj` flags belong to
//! the patterns that *continue* below it; terminal riders merge on step
//! equality alone.

use super::{Plan, Step};

/// Index of a node in its program's arena.
pub type NodeId = usize;

/// One trie node: a level of one or more plans whose prefixes coincide.
#[derive(Clone, Debug)]
pub struct ProgramNode {
    /// Matching level of this node (0 = root scan).
    pub level: usize,
    /// The step extending level-1 ancestors into this node; `None` for
    /// root nodes (level 0 enumerates start vertices).
    pub step: Option<Step>,
    /// Whether the candidate set computed *at this node* is stored for
    /// reuse by descendants (vertical sharing). Owned by the continuing
    /// patterns; meaningless when none continue.
    pub store: bool,
    /// Whether the adjacency list of the vertex matched at this node is
    /// active (needed by some later step of a continuing pattern).
    pub needs_adj: bool,
    /// Root nodes only: required label of the start vertices (0 = any).
    pub label0: u8,
    /// Whether `store`/`needs_adj` have been claimed by a continuing
    /// pattern (a node created by a terminal rider leaves them open).
    flags_set: bool,
    /// Child nodes, in first-plan order (the engine's deterministic
    /// extension order).
    pub children: Vec<NodeId>,
    /// Patterns alive at this node (passing through or terminating),
    /// ascending program indices.
    pub pats: Vec<usize>,
    /// Patterns continuing below this node (`pats` minus `terminal`).
    pub cont: Vec<usize>,
    /// Patterns whose last matching level is exactly this node.
    pub terminal: Vec<usize>,
}

impl ProgramNode {
    fn new_root(label0: u8, needs_adj: bool) -> Self {
        ProgramNode {
            level: 0,
            step: None,
            store: false,
            needs_adj,
            label0,
            flags_set: true,
            children: Vec::new(),
            pats: Vec::new(),
            cont: Vec::new(),
            terminal: Vec::new(),
        }
    }

    /// Position of pattern `p` in this node's `cont` list (the engine's
    /// per-frame attribution slot). Frames, fetches, and tasks at a node
    /// involve only the *continuing* patterns — a terminal rider's last
    /// level is bulk-processed from the candidate window at the parent
    /// frame and never materialises here.
    #[inline]
    pub fn slot_of(&self, p: usize) -> usize {
        self.cont.iter().position(|&q| q == p).expect("pattern continues at node")
    }

    /// Whether any pattern continues below this node (the node's frames
    /// produce child chunks).
    #[inline]
    pub fn interior(&self) -> bool {
        !self.cont.is_empty()
    }
}

/// A compiled multi-pattern mining program: the plans plus their merged
/// prefix trie. Built once per job by [`MiningProgram::compile`] and
/// interpreted generically by the engine ([`crate::engine::KuduEngine::run_program`])
/// or as a plain plan list by the baselines.
#[derive(Clone, Debug)]
pub struct MiningProgram {
    plans: Vec<Plan>,
    nodes: Vec<ProgramNode>,
    roots: Vec<NodeId>,
}

impl MiningProgram {
    /// Compile `plans` into a program. With `fuse`, maximal compatible
    /// prefixes are merged; without it only root nodes merge (one root
    /// scan, per-pattern chains below — the mode used when an app
    /// installs [`crate::engine::sink::ExtendHooks`], whose per-pattern
    /// control flow would make deeper shared frames diverge).
    ///
    /// Two plans share a node at level `l ≥ 1` only when their steps at
    /// every level `≤ l` are equal — same backward sources, same
    /// symmetry restrictions (`greater_than`/`less_than`), same label and
    /// exclusion constraints — and, for levels some pattern continues
    /// past, the same `store_set`/`needs_adj` flags. Equal restrictions
    /// are what make a shared frame's candidate windows, and therefore
    /// its chunk contents, bit-identical to each pattern's own run.
    pub fn compile(plans: Vec<Plan>, fuse: bool) -> MiningProgram {
        assert!(!plans.is_empty(), "a program mines at least one pattern");
        let mut nodes: Vec<ProgramNode> = Vec::new();
        let mut roots: Vec<NodeId> = Vec::new();
        for (i, plan) in plans.iter().enumerate() {
            let k = plan.depth();
            assert!(k >= 2, "patterns must have at least one edge");
            let l0 = plan.pattern.label(0);
            let needs0 = plan.needs_adj[0];
            let root = match roots
                .iter()
                .copied()
                .find(|&r| nodes[r].label0 == l0 && nodes[r].needs_adj == needs0)
            {
                Some(r) => r,
                None => {
                    nodes.push(ProgramNode::new_root(l0, needs0));
                    roots.push(nodes.len() - 1);
                    nodes.len() - 1
                }
            };
            nodes[root].pats.push(i);
            nodes[root].cont.push(i);
            let mut cur = root;
            for l in 1..k {
                let step = &plan.steps[l - 1];
                let terminal_here = l == k - 1;
                let want_store = plan.store_set[l] && !terminal_here;
                let want_needs = plan.needs_adj[l] && !terminal_here;
                let found = if fuse {
                    nodes[cur].children.iter().copied().find(|&c| {
                        nodes[c].step.as_ref() == Some(step)
                            && (terminal_here
                                || !nodes[c].flags_set
                                || (nodes[c].store == want_store
                                    && nodes[c].needs_adj == want_needs))
                    })
                } else {
                    None
                };
                let child = match found {
                    Some(c) => {
                        if !terminal_here && !nodes[c].flags_set {
                            nodes[c].store = want_store;
                            nodes[c].needs_adj = want_needs;
                            nodes[c].flags_set = true;
                        }
                        c
                    }
                    None => {
                        nodes.push(ProgramNode {
                            level: l,
                            step: Some(step.clone()),
                            store: want_store,
                            needs_adj: want_needs,
                            label0: 0,
                            flags_set: !terminal_here,
                            children: Vec::new(),
                            pats: Vec::new(),
                            cont: Vec::new(),
                            terminal: Vec::new(),
                        });
                        let c = nodes.len() - 1;
                        nodes[cur].children.push(c);
                        c
                    }
                };
                nodes[child].pats.push(i);
                if terminal_here {
                    nodes[child].terminal.push(i);
                } else {
                    nodes[child].cont.push(i);
                }
                cur = child;
            }
        }
        MiningProgram { plans, nodes, roots }
    }

    /// The program's plans, in pattern order.
    pub fn plans(&self) -> &[Plan] {
        &self.plans
    }

    /// Number of patterns the program mines.
    pub fn num_patterns(&self) -> usize {
        self.plans.len()
    }

    /// Deepest matching level over all plans.
    pub fn max_depth(&self) -> usize {
        self.plans.iter().map(|p| p.depth()).max().unwrap_or(0)
    }

    #[inline]
    pub fn node(&self, id: NodeId) -> &ProgramNode {
        &self.nodes[id]
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Root nodes (level-0 scans), one per compatible (root label,
    /// root-activity) group. A fully fused counting program usually has
    /// exactly one.
    pub fn roots(&self) -> &[NodeId] {
        &self.roots
    }

    /// Nodes shared by more than one pattern — the frames the engine
    /// explores once instead of once per pattern.
    pub fn shared_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.pats.len() > 1).count()
    }

    /// Sum over plans of their level count — what a per-pattern
    /// execution explores; `num_nodes()` is what the fused trie
    /// explores. The gap is the sharing.
    pub fn chain_nodes(&self) -> usize {
        self.plans.iter().map(|p| p.depth()).sum()
    }

    /// Human-readable trie dump (tests, `kudu plan` debugging).
    pub fn describe(&self) -> String {
        fn rec(prog: &MiningProgram, id: NodeId, depth: usize, out: &mut String) {
            let n = prog.node(id);
            let indent = "  ".repeat(depth + 1);
            out.push_str(&format!(
                "{indent}level {} pats={:?}{}{}{}\n",
                n.level,
                n.pats,
                if n.terminal.is_empty() {
                    String::new()
                } else {
                    format!(" terminal={:?}", n.terminal)
                },
                if n.store { " [store]" } else { "" },
                if n.needs_adj { " [adj active]" } else { "" },
            ));
            for &c in &n.children {
                rec(prog, c, depth + 1, out);
            }
        }
        let mut s = format!(
            "program: {} patterns, {} trie nodes ({} shared) vs {} chain nodes\n",
            self.num_patterns(),
            self.num_nodes(),
            self.shared_nodes(),
            self.chain_nodes()
        );
        for &r in &self.roots {
            rec(self, r, 0, &mut s);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::brute::Induced;
    use crate::pattern::{motifs, Pattern};
    use crate::plan::{automine_plan, graphpi_plan};

    #[test]
    fn single_plan_program_is_a_chain() {
        let plan = graphpi_plan(&Pattern::clique(4), Induced::Edge);
        let prog = MiningProgram::compile(vec![plan.clone()], true);
        assert_eq!(prog.num_patterns(), 1);
        assert_eq!(prog.num_nodes(), plan.depth());
        assert_eq!(prog.roots().len(), 1);
        assert_eq!(prog.shared_nodes(), 0);
        // Chain structure: every node has at most one child; the last is
        // terminal for pattern 0.
        let mut cur = prog.roots()[0];
        for _ in 0..plan.depth() - 1 {
            assert_eq!(prog.node(cur).children.len(), 1);
            cur = prog.node(cur).children[0];
        }
        assert!(prog.node(cur).children.is_empty());
        assert_eq!(prog.node(cur).terminal, vec![0]);
        assert!(!prog.node(cur).interior());
    }

    #[test]
    fn identical_plans_fuse_completely() {
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let prog = MiningProgram::compile(vec![plan.clone(), plan.clone()], true);
        // Full overlap: the trie is one chain, every node shared.
        assert_eq!(prog.num_nodes(), plan.depth());
        assert_eq!(prog.shared_nodes(), plan.depth());
        let last = (0..prog.num_nodes())
            .find(|&i| !prog.node(i).terminal.is_empty())
            .unwrap();
        assert_eq!(prog.node(last).terminal, vec![0, 1]);
    }

    #[test]
    fn unfused_program_merges_only_roots() {
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let prog = MiningProgram::compile(vec![plan.clone(), plan.clone()], false);
        assert_eq!(prog.roots().len(), 1, "roots always merge");
        // Below the root: disjoint per-pattern chains.
        assert_eq!(prog.num_nodes(), 1 + 2 * (plan.depth() - 1));
        assert_eq!(prog.node(prog.roots()[0]).children.len(), 2);
        assert_eq!(prog.shared_nodes(), 1);
    }

    #[test]
    fn motif_program_shares_root_scan_and_prefixes() {
        for client in [automine_plan, graphpi_plan] {
            let plans: Vec<Plan> =
                motifs::all_motifs(4).iter().map(|p| client(p, Induced::Vertex)).collect();
            let prog = MiningProgram::compile(plans, true);
            assert_eq!(prog.roots().len(), 1, "all 4-motifs share one root scan");
            assert_eq!(prog.node(prog.roots()[0]).pats.len(), 6);
            // The trie is strictly smaller than the six chains laid side
            // by side (prefix sharing beyond the root).
            assert!(
                prog.num_nodes() < prog.chain_nodes(),
                "nodes {} !< chains {}:\n{}",
                prog.num_nodes(),
                prog.chain_nodes(),
                prog.describe()
            );
            assert!(prog.shared_nodes() >= 2, "sharing beyond the root:\n{}", prog.describe());
        }
    }

    #[test]
    fn incompatible_restrictions_do_not_merge() {
        // Clique-4 (v0<v1 at level 1) and star-4 (no level-1 restriction)
        // must not share level-1 frames: their candidate windows differ.
        let a = automine_plan(&Pattern::clique(4), Induced::Edge);
        let b = automine_plan(&Pattern::star(4), Induced::Edge);
        let s1a = &a.steps[0];
        let s1b = &b.steps[0];
        assert_ne!(
            (&s1a.greater_than, &s1a.less_than),
            (&s1b.greater_than, &s1b.less_than),
            "test premise: restriction placement differs"
        );
        let prog = MiningProgram::compile(vec![a, b], true);
        let root = prog.node(prog.roots()[0]);
        if root.pats.len() == 2 {
            // Shared root, split immediately below.
            assert_eq!(root.children.len(), 2);
        }
    }

    #[test]
    fn mixed_depth_terminal_rides_inside_longer_chain() {
        // A 3-chain whose plan is a prefix of the 4-chain's plan (when
        // compatible) terminates at an interior node of the 4-chain.
        let p3 = automine_plan(&Pattern::chain(2), Induced::Edge); // single edge
        let p4 = automine_plan(&Pattern::chain(3), Induced::Edge);
        let prog = MiningProgram::compile(vec![p3, p4], true);
        // Whether or not level 1 merged, every pattern has exactly one
        // terminal node and the trie is consistent.
        let mut term = [0usize; 2];
        for i in 0..prog.num_nodes() {
            for &p in &prog.node(i).terminal {
                term[p] += 1;
            }
        }
        assert_eq!(term, [1, 1]);
    }

    #[test]
    fn describe_mentions_sharing() {
        let plans: Vec<Plan> = motifs::all_motifs(3)
            .iter()
            .map(|p| graphpi_plan(p, Induced::Vertex))
            .collect();
        let prog = MiningProgram::compile(plans, true);
        let d = prog.describe();
        assert!(d.contains("2 patterns"));
        assert!(d.contains("level 0"));
    }
}
