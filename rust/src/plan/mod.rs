//! Pattern-aware matching plans — the "code generator" layer.
//!
//! A [`Plan`] is the compiled form of a pattern enumeration algorithm
//! (the nested intersection loops of paper Fig. 2): a matching order, the
//! backward-neighbour sets to intersect at each level, symmetry-breaking
//! restrictions, and vertical-sharing (reusable intersection) annotations.
//!
//! Two planners are provided, mirroring the two client systems the paper
//! ports onto Kudu:
//! * [`automine_plan`] — Automine-style: connectivity-greedy matching
//!   order, orbit-stabiliser symmetry breaking on that order.
//! * [`graphpi_plan`] — GraphPi-style: searches all connectivity-respecting
//!   orders and picks the one minimising an estimated enumeration cost
//!   (GraphPi's "effective redundancy elimination" — better restriction
//!   placement, which is why k-GraphPi beats k-Automine on 3-MC in
//!   Table 3).
//!
//! The Kudu engine interprets plans generically; porting a new client
//! system is writing a new planner (the paper's ~500-line "modify the code
//! generator" porting cost).

pub mod program;
pub mod restrict;

use crate::pattern::brute::Induced;
use crate::pattern::Pattern;
pub use program::{MiningProgram, NodeId, ProgramNode};
pub use restrict::symmetry_restrictions;

/// One source feeding the candidate-set intersection at some level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// The adjacency list of the vertex matched at this earlier level.
    Adj(usize),
    /// The stored (unfiltered) candidate set computed at this earlier
    /// level — vertical computation sharing (paper §6.1).
    Stored(usize),
}

/// Per-level step of the plan. `PartialEq` is structural — the program
/// compiler ([`MiningProgram::compile`]) merges two plans' levels only
/// when their steps compare equal (the restriction compatibility check).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Step {
    /// Levels of earlier pattern vertices adjacent to this one (the
    /// backward neighbours B_i). Non-empty for every level ≥ 1 — matching
    /// orders are connectivity-respecting.
    pub backward: Vec<usize>,
    /// What to intersect to form the candidate set. Either the raw
    /// adjacency lists of `backward`, or a stored ancestor set plus the
    /// leftover adjacency lists.
    pub sources: Vec<Source>,
    /// Earlier levels j such that the symmetry-breaking restriction
    /// v_j < v_i applies at this level i.
    pub greater_than: Vec<usize>,
    /// Earlier levels j such that v_j > v_i is required (the mirror
    /// restriction direction).
    pub less_than: Vec<usize>,
    /// Earlier non-adjacent levels whose neighbourhoods must be *excluded*
    /// (vertex-induced semantics only).
    pub exclude: Vec<usize>,
    /// Required vertex label at this level (0 = unconstrained).
    pub label: u8,
}

/// A compiled enumeration plan for one pattern.
#[derive(Clone, Debug)]
pub struct Plan {
    /// The pattern *in matching order* (vertex i of this pattern is
    /// matched at level i).
    pub pattern: Pattern,
    /// Steps for levels 1..k (level 0 enumerates all vertices).
    pub steps: Vec<Step>,
    /// Embedding semantics.
    pub induced: Induced,
    /// `store_set[i]` — the candidate set computed at level i must be
    /// stored in the extendable embedding for reuse by descendants.
    pub store_set: Vec<bool>,
    /// `needs_adj[i]` — the adjacency list of the vertex matched at level
    /// i is an *active edge list* for some later step and must be fetched
    /// / retained (the paper's "active vertex" notion; antimonotone).
    pub needs_adj: Vec<bool>,
    /// Restrictions as raw (a, b) pairs meaning v_a < v_b, for reporting.
    pub restrictions: Vec<(usize, usize)>,
}

impl Plan {
    /// Number of levels (pattern vertices).
    pub fn depth(&self) -> usize {
        self.pattern.num_vertices()
    }

    /// The overcount factor the restrictions cancel (|Aut(pattern)|).
    pub fn automorphism_factor(&self) -> u64 {
        self.pattern.automorphisms().len() as u64
    }

    /// Strip vertical computation sharing (the Fig 13 ablation): every
    /// step intersects raw adjacency lists; nothing is stored.
    pub fn without_vertical_sharing(&self) -> Plan {
        let mut p = self.clone();
        for (i, st) in p.steps.iter_mut().enumerate() {
            st.sources = st.backward.iter().map(|&l| Source::Adj(l)).collect();
            let _ = i;
        }
        for s in p.store_set.iter_mut() {
            *s = false;
        }
        // Recompute active vertices from the widened source lists.
        let k = p.pattern.num_vertices();
        let mut needs = vec![false; k];
        for (i, st) in p.steps.iter().enumerate() {
            for s in &st.sources {
                if let Source::Adj(l) = s {
                    needs[*l] = true;
                }
            }
            if p.induced == Induced::Vertex {
                for j in 0..(i + 1) {
                    if !p.pattern.has_edge(j, i + 1) {
                        needs[j] = true;
                    }
                }
            }
        }
        p.needs_adj = needs;
        p
    }

    /// Human-readable plan dump (used by `kudu plan` CLI).
    pub fn describe(&self) -> String {
        let mut s = format!(
            "plan: k={} edges={:?} induced={:?} |Aut|={}\n",
            self.depth(),
            self.pattern.edges(),
            self.induced,
            self.automorphism_factor()
        );
        for (i, st) in self.steps.iter().enumerate() {
            let lvl = i + 1;
            s += &format!(
                "  level {lvl}: sources={:?} restrict>[{:?}] <[{:?}] exclude={:?}{}{}\n",
                st.sources,
                st.greater_than,
                st.less_than,
                st.exclude,
                if self.store_set[lvl] { " [store]" } else { "" },
                if self.needs_adj[lvl] { " [adj active]" } else { "" },
            );
        }
        s
    }
}

/// Build the steps for a given matching order (identity order of `p`),
/// deriving sources with vertical sharing, restriction placement, and
/// active-vertex flags.
fn build_plan(p: &Pattern, induced: Induced, restrictions: &[(usize, usize)]) -> Plan {
    let k = p.num_vertices();
    let mut steps = Vec::with_capacity(k - 1);
    // Backward sets.
    let backward: Vec<Vec<usize>> = (0..k)
        .map(|i| (0..i).filter(|&j| p.has_edge(j, i)).collect::<Vec<_>>())
        .collect();

    // Vertical sharing: for level i, find the deepest earlier level j ≥ 2
    // whose backward set is a subset of B_i with |B_j| ≥ 2 (a level-1 set
    // is a single adjacency list — nothing to reuse). The stored set C_j
    // is the *unfiltered* intersection over B_j, so C_i = C_j ∩ (the
    // leftover adjacency lists).
    let mut store_set = vec![false; k];
    let mut sources: Vec<Vec<Source>> = vec![Vec::new(); k];
    for i in 1..k {
        let bi = &backward[i];
        let mut best: Option<usize> = None;
        for j in (2..i).rev() {
            let bj = &backward[j];
            if bj.len() >= 2
                && bj.len() < bi.len()
                && bj.iter().all(|x| bi.contains(x))
            {
                best = Some(j);
                break;
            }
        }
        match best {
            Some(j) => {
                store_set[j] = true;
                let mut src = vec![Source::Stored(j)];
                for &l in bi {
                    if !backward[j].contains(&l) {
                        src.push(Source::Adj(l));
                    }
                }
                sources[i] = src;
            }
            None => {
                sources[i] = bi.iter().map(|&l| Source::Adj(l)).collect();
            }
        }
    }

    // Active vertices: N(v_l) is needed if Adj(l) appears in a later step,
    // or (vertex-induced) if l is excluded at a later step.
    let mut needs_adj = vec![false; k];
    for i in 1..k {
        for s in &sources[i] {
            if let Source::Adj(l) = s {
                needs_adj[*l] = true;
            }
        }
        if induced == Induced::Vertex {
            for j in 0..i {
                if !p.has_edge(j, i) {
                    needs_adj[j] = true;
                }
            }
        }
    }

    for i in 1..k {
        let greater_than: Vec<usize> =
            restrictions.iter().filter(|&&(a, b)| b == i && a < i).map(|&(a, _)| a).collect();
        let less_than: Vec<usize> =
            restrictions.iter().filter(|&&(a, b)| a == i && b < i).map(|&(_, b)| b).collect();
        let exclude: Vec<usize> = if induced == Induced::Vertex {
            (0..i).filter(|&j| !p.has_edge(j, i)).collect()
        } else {
            Vec::new()
        };
        steps.push(Step {
            backward: backward[i].clone(),
            sources: sources[i].clone(),
            greater_than,
            less_than,
            exclude,
            label: p.label(i),
        });
    }

    Plan {
        pattern: p.clone(),
        steps,
        induced,
        store_set,
        needs_adj,
        restrictions: restrictions.to_vec(),
    }
}

/// All connectivity-respecting matching orders (each vertex after the
/// first has an earlier neighbour).
fn connected_orders(p: &Pattern) -> Vec<Vec<usize>> {
    let k = p.num_vertices();
    let mut out = Vec::new();
    let mut order = Vec::with_capacity(k);
    fn rec(p: &Pattern, order: &mut Vec<usize>, used: u8, out: &mut Vec<Vec<usize>>) {
        let k = p.num_vertices();
        if order.len() == k {
            out.push(order.clone());
            return;
        }
        for v in 0..k {
            if used & (1 << v) != 0 {
                continue;
            }
            if !order.is_empty() && p.adj_bits(v) & used == 0 {
                continue; // not connected to the prefix
            }
            order.push(v);
            rec(p, order, used | (1 << v), out);
            order.pop();
        }
    }
    rec(p, &mut order, 0, &mut out);
    out
}

/// Estimated enumeration cost of an order — GraphPi-style scoring.
/// Prefers: high-degree-in-pattern vertices early (more constrained
/// candidate sets sooner), restrictions applying early (symmetry pruning
/// high in the tree), and more backward neighbours per level.
fn order_cost(p: &Pattern, order: &[usize]) -> f64 {
    let q = p.permute(order);
    let restr = symmetry_restrictions(&q);
    let k = q.num_vertices();
    let mut cost = 0.0;
    // Cost model: the candidate-set size at level i shrinks geometrically
    // with the number of constraints already applied; each restriction at
    // level ≤ i halves the subtree.
    let mut width = 1.0f64;
    for i in 1..k {
        let b = (0..i).filter(|&j| q.has_edge(j, i)).count();
        let r = restr.iter().filter(|&&(a, bb)| bb == i && a < i).count();
        // More intersections => smaller candidate sets; restrictions prune.
        let shrink = 0.5f64.powi(b as i32 - 1) * 0.6f64.powi(r as i32);
        width *= 8.0 * shrink; // 8.0: nominal average degree scale
        cost += width;
    }
    cost
}

/// Automine-style plan: greedy connectivity order (maximise backward
/// connections, break ties by pattern degree then index), then
/// orbit-stabiliser restrictions.
pub fn automine_plan(p: &Pattern, induced: Induced) -> Plan {
    assert!(p.is_connected(), "GPM patterns must be connected");
    let k = p.num_vertices();
    let mut order: Vec<usize> = Vec::with_capacity(k);
    let mut used = 0u8;
    // Start from the max-degree vertex.
    let start = (0..k).max_by_key(|&v| (p.degree(v), k - v)).unwrap();
    order.push(start);
    used |= 1 << start;
    while order.len() < k {
        let next = (0..k)
            .filter(|&v| used & (1 << v) == 0 && p.adj_bits(v) & used != 0)
            .max_by_key(|&v| ((p.adj_bits(v) & used).count_ones(), p.degree(v), k - v))
            .expect("connected pattern always has a next vertex");
        order.push(next);
        used |= 1 << next;
    }
    let q = p.permute(&order);
    let restr = symmetry_restrictions(&q);
    build_plan(&q, induced, &restr)
}

/// GraphPi-style plan: exhaustive search over connectivity-respecting
/// orders, scored by [`order_cost`]. Exact at pattern sizes ≤ 8.
pub fn graphpi_plan(p: &Pattern, induced: Induced) -> Plan {
    assert!(p.is_connected(), "GPM patterns must be connected");
    let orders = connected_orders(p);
    let best = orders
        .into_iter()
        .min_by(|a, b| order_cost(p, a).partial_cmp(&order_cost(p, b)).unwrap())
        .expect("connected pattern has at least one order");
    let q = p.permute(&best);
    let restr = symmetry_restrictions(&q);
    build_plan(&q, induced, &restr)
}

/// Which client system generated the plan — selects the planner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClientSystem {
    /// k-Automine (greedy order).
    Automine,
    /// k-GraphPi (cost-searched order).
    GraphPi,
}

impl ClientSystem {
    pub fn plan(&self, p: &Pattern, induced: Induced) -> Plan {
        match self {
            ClientSystem::Automine => automine_plan(p, induced),
            ClientSystem::GraphPi => graphpi_plan(p, induced),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ClientSystem::Automine => "k-Automine",
            ClientSystem::GraphPi => "k-GraphPi",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::Pattern;

    #[test]
    fn triangle_plan_shape() {
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        assert_eq!(plan.depth(), 3);
        assert_eq!(plan.steps.len(), 2);
        // Level 2 intersects N(v0) ∩ N(v1).
        assert_eq!(plan.steps[1].sources.len(), 2);
        // Triangle restrictions give v0 < v1 < v2 (some orientation).
        assert_eq!(plan.automorphism_factor(), 6);
        assert_eq!(plan.restrictions.len(), 3);
    }

    #[test]
    fn clique_plans_use_vertical_sharing() {
        for k in 4..=6 {
            let plan = automine_plan(&Pattern::clique(k), Induced::Edge);
            // Levels 3..k-1 must reuse the stored set of their parent.
            for i in 3..k {
                let st = &plan.steps[i - 1];
                assert!(
                    matches!(st.sources[0], Source::Stored(_)),
                    "k={k} level {i} should reuse: {:?}",
                    st.sources
                );
                assert_eq!(st.sources.len(), 2, "reuse + one new adjacency");
            }
        }
    }

    #[test]
    fn needs_adj_antimonotone_for_last_level() {
        // The vertex matched at the last level never needs its adjacency.
        for p in [Pattern::triangle(), Pattern::clique(4), Pattern::chain(4)] {
            let plan = automine_plan(&p, Induced::Edge);
            assert!(!plan.needs_adj[plan.depth() - 1]);
        }
    }

    #[test]
    fn chain_orders_are_connected() {
        let plan = graphpi_plan(&Pattern::chain(4), Induced::Edge);
        for st in &plan.steps {
            assert!(!st.backward.is_empty(), "order must be connectivity-respecting");
        }
    }

    #[test]
    fn connected_orders_counts() {
        // Triangle: all 3! = 6 orders are connected.
        assert_eq!(connected_orders(&Pattern::triangle()).len(), 6);
        // 3-chain 0-1-2: orders starting with (0,2) are disconnected at
        // step 2; connected orders = 6 - 2 = ... enumerate: valid orders
        // are those where the second vertex neighbours the first:
        // 0,1,_ ; 1,0,_ ; 1,2,_ ; 2,1,_ and then the third must attach:
        // all do. Plus 0,1,2 / 1,{0,2} both orders / 2,1,0 => 4 prefixes
        // × 1 = 4... second vertex choices: from 0: only 1; from 1: 0 or
        // 2; from 2: only 1 => 4 orders.
        assert_eq!(connected_orders(&Pattern::chain(3)).len(), 4);
    }

    #[test]
    fn vertex_induced_excludes_nonneighbors() {
        let plan = automine_plan(&Pattern::chain(3), Induced::Vertex);
        // The last level of a 3-chain has exactly one non-neighbour among
        // earlier levels.
        assert_eq!(plan.steps[1].exclude.len(), 1);
        // Edge-induced: no exclusions.
        let plan_e = automine_plan(&Pattern::chain(3), Induced::Edge);
        assert!(plan_e.steps[1].exclude.is_empty());
    }

    #[test]
    fn describe_is_nonempty() {
        let plan = graphpi_plan(&Pattern::clique(4), Induced::Edge);
        assert!(plan.describe().contains("level 3"));
    }

    /// Golden pin of `Plan::describe()` on the 4-clique, for both
    /// planners. Every order of a clique yields the same permuted
    /// pattern, so the step structure is planner-independent and can be
    /// pinned line by line: the full orbit–stabiliser restriction chain
    /// v0 < v1 < v2 < v3 and vertical sharing at level 3 (level 2's
    /// unfiltered N(v0) ∩ N(v1) reused as Stored(2)).
    #[test]
    fn golden_clique4_describe_both_planners() {
        for (name, plan) in [
            ("automine", automine_plan(&Pattern::clique(4), Induced::Edge)),
            ("graphpi", graphpi_plan(&Pattern::clique(4), Induced::Edge)),
        ] {
            let d = plan.describe();
            assert!(d.contains("k=4"), "{name}: {d}");
            assert!(d.contains("|Aut|=24"), "{name}: {d}");
            assert!(
                d.contains("level 1: sources=[Adj(0)] restrict>[[0]] <[[]] exclude=[]"),
                "{name}: {d}"
            );
            assert!(
                d.contains("level 2: sources=[Adj(0), Adj(1)] restrict>[[0, 1]] <[[]] exclude=[]"),
                "{name}: {d}"
            );
            assert!(
                d.contains("level 3: sources=[Stored(2), Adj(2)] restrict>[[0, 1, 2]] <[[]] exclude=[]"),
                "{name}: {d}"
            );
            // Level 2's candidate set is the one stored for reuse; its
            // line carries the [store] marker.
            let l2 = d.lines().find(|l| l.trim_start().starts_with("level 2")).unwrap();
            assert!(l2.ends_with("[store] [adj active]"), "{name}: {l2}");
            // Restrictions are reported as raw pairs too.
            assert_eq!(
                plan.restrictions,
                vec![(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)],
                "{name}"
            );
            // describe() is a pure function of the plan.
            assert_eq!(d, plan.describe(), "{name}: describe must be stable");
        }
    }

    /// Golden invariants of every 4-motif plan under both planners:
    /// depth, automorphism factor (reported in the describe header),
    /// orbit-product exactness, and vertex-induced exclusions appearing
    /// exactly for the non-complete motifs.
    #[test]
    fn golden_four_motif_plans_automine_vs_graphpi() {
        use crate::pattern::motifs::all_motifs;
        let expected: [(Pattern, u64); 6] = [
            (Pattern::clique(4), 24),
            (Pattern::cycle(4), 8),
            (Pattern::star(4), 6),
            (Pattern::diamond(), 4),
            (Pattern::chain(4), 2),
            (Pattern::tailed_triangle(), 2),
        ];
        for motif in all_motifs(4) {
            let (_, aut) = expected
                .iter()
                .find(|(p, _)| motif.isomorphic(p))
                .expect("every 4-motif is one of the six known shapes");
            for (name, plan) in [
                ("automine", automine_plan(&motif, Induced::Vertex)),
                ("graphpi", graphpi_plan(&motif, Induced::Vertex)),
            ] {
                let d = plan.describe();
                assert_eq!(plan.depth(), 4, "{name} {motif:?}");
                assert_eq!(plan.automorphism_factor(), *aut, "{name} {motif:?}");
                assert!(d.contains(&format!("|Aut|={aut}")), "{name} {motif:?}: {d}");
                assert!(d.contains("level 3:"), "{name} {motif:?}: {d}");
                assert!(!d.contains("level 4:"), "{name} {motif:?}: {d}");
                // Orbit product == |Aut|: the restriction set cancels the
                // overcount exactly (cross-checked against brute force in
                // tests/proptests.rs).
                assert_eq!(
                    restrict::restriction_factor(&plan.pattern),
                    *aut,
                    "{name} {motif:?}"
                );
                // Vertex-induced: exactly the non-complete motifs exclude.
                let excludes = plan.steps.iter().any(|s| !s.exclude.is_empty());
                assert_eq!(excludes, *aut != 24, "{name} {motif:?}");
                // Matching orders are connectivity-respecting.
                assert!(plan.steps.iter().all(|s| !s.backward.is_empty()), "{name} {motif:?}");
            }
        }
    }
}
