//! Run configuration: which engine features are on, cluster shape, chunk
//! sizes, scheduler granularity — everything the ablation tables toggle.

use crate::metrics::{ComputeModel, NetModel};

pub use crate::comm::CommConfig;

/// A degenerate [`EngineConfig`] rejected by [`EngineConfig::validate`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// `chunk_capacity == 0`: a zero-capacity chunk can never fill nor
    /// hold an embedding, so exploration would loop forever.
    ZeroChunkCapacity,
    /// `mini_batch == 0`: the virtual-time model divides work into
    /// mini-batches; zero would divide by zero.
    ZeroMiniBatch,
    /// `sockets == 0`: a machine has at least one NUMA socket.
    ZeroSockets,
    /// `comm.max_in_flight == 0`: a machine with no in-flight budget
    /// could never issue a remote fetch, so any multi-machine run would
    /// stall forever. The synchronous setting is `max_in_flight = 1`
    /// (or `comm.sync_fetch = true` to bypass messaging entirely).
    ZeroInFlight,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroChunkCapacity => {
                write!(f, "chunk_capacity must be >= 1 (a zero-capacity chunk cannot hold any embedding)")
            }
            ConfigError::ZeroMiniBatch => {
                write!(f, "mini_batch must be >= 1 (work is distributed in mini-batches)")
            }
            ConfigError::ZeroSockets => write!(f, "sockets must be >= 1"),
            ConfigError::ZeroInFlight => write!(
                f,
                "comm.max_in_flight must be >= 1 (use 1 for synchronous round trips, \
                 or comm.sync_fetch = true to bypass the comm subsystem)"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Read a host-parallelism default from the environment (used by the CI
/// determinism matrix: `KUDU_SIM_THREADS=1 KUDU_WORKERS_PER_MACHINE=1
/// cargo test` must report bit-identical numbers to the all-cores run).
fn env_knob(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Which graph representation the run materializes and mines over — see
/// [`crate::graph::GraphStore`]. Purely a wall-clock/footprint knob: the
/// determinism contract guarantees counts, traffic, and virtual time are
/// bitwise identical across tiers (`tests/sched_determinism.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StorageTier {
    /// Plain `Vec`-backed CSR (the default and reference tier).
    Csr,
    /// Varint-delta block-compressed adjacency
    /// ([`crate::graph::CompactGraph`]), ~2–2.5× smaller; decode charges
    /// land in the `decode_s` diagnostic.
    Compact,
}

impl StorageTier {
    /// Apply the process-wide `KUDU_NO_COMPACT` escape hatch (mirrors
    /// `KUDU_NO_SIMD` for kernels): when set, every run is forced onto
    /// the CSR tier regardless of config. Read once per process.
    pub fn resolve(self) -> StorageTier {
        static NO_COMPACT: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        let off = *NO_COMPACT.get_or_init(|| std::env::var_os("KUDU_NO_COMPACT").is_some());
        if off {
            StorageTier::Csr
        } else {
            self
        }
    }
}

impl Default for StorageTier {
    /// CSR unless `KUDU_COMPACT_GRAPH` is set (the CI determinism matrix
    /// uses the env form to run the whole suite on the compact tier).
    fn default() -> Self {
        if std::env::var_os("KUDU_COMPACT_GRAPH").is_some() {
            StorageTier::Compact
        } else {
            StorageTier::Csr
        }
    }
}

/// Kudu engine feature toggles and sizing (paper §5–§6 knobs).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Chunk capacity: number of extendable embeddings per level chunk
    /// (the paper pre-allocates ~1 GB per level; we size by count).
    pub chunk_capacity: usize,
    /// Mini-batch size for work distribution (paper §7: 64). Also the
    /// root-vertex granularity of scheduler tasks: each root task explores
    /// the subtrees of one `mini_batch`-sized slice of a machine's owned
    /// start vertices.
    pub mini_batch: usize,
    /// Vertical computation sharing (paper §6.1 / Fig 13).
    pub vertical_sharing: bool,
    /// Horizontal data sharing (paper §6.2 / Fig 14).
    pub horizontal_sharing: bool,
    /// Static cache size as a fraction of graph CSR bytes (paper §6.3:
    /// 5–10%); `0.0` disables the cache (Table 6 "no cache").
    pub cache_frac: f64,
    /// Degree threshold for cache insertion (the paper uses 64 at
    /// billion-edge scale; scaled to 16 for the laptop-scale stand-ins so
    /// the cached set covers the same fraction of traffic).
    pub cache_degree_threshold: usize,
    /// NUMA sockets per machine; `1` disables NUMA modelling.
    pub sockets: usize,
    /// NUMA-aware exploration (Table 7); irrelevant when `sockets == 1`.
    pub numa_aware: bool,
    /// Computation threads per machine (virtual; Fig 17). This is part of
    /// the *cost model* — it scales virtual compute time.
    pub threads: usize,
    /// Host threads used to execute the simulation itself. `0` = all
    /// available cores (overridable via `KUDU_SIM_THREADS`). Changes
    /// wall-clock time only: counts, traffic, and virtual-time metrics are
    /// byte-for-byte identical for every value.
    pub sim_threads: usize,
    /// Logical scheduler workers per simulated machine. Each machine's
    /// chunk-granularity tasks run on this many per-worker deques with
    /// work stealing; the host multiplexes all machines' workers onto
    /// `sim_threads` threads. `0` = all available cores (overridable via
    /// `KUDU_WORKERS_PER_MACHINE`). Like `sim_threads`, this knob changes
    /// wall-clock time only — the task decomposition and every reduction
    /// order are fixed by graph + config, never by worker count or steal
    /// interleaving.
    pub workers_per_machine: usize,
    /// Task-split depth budget: a task exploring a frame at `level <
    /// task_split_levels` hands each full child chunk to the scheduler as
    /// a new task (instead of descending depth-first in place). `0`
    /// disables splitting — every root task explores its whole subtree.
    pub task_split_levels: usize,
    /// Task-split width budget: at most this many child tasks are split
    /// off per (task, trie child edge); further full child chunks are
    /// descended depth-first in place. Bounds the memory a single skewed
    /// task can pin. Counting the budget per child edge (rather than per
    /// task) is what lets every pattern sharing a fused program's edge
    /// observe identical split decisions — the per-pattern task trees
    /// stay exactly those of the patterns' single-plan runs.
    pub task_split_width: usize,
    /// Cap on split-off child chunks buffered in a machine's scheduler
    /// queues. Above the cap, a would-be child task is parked on the
    /// spawning worker's private overflow stack and becomes that
    /// worker's *next* task (depth-first, releasing its chunk soonest) —
    /// task identity and results are unchanged, only *where* the task
    /// runs. The same cap bounds frames parked on in-flight comm
    /// responses (past it, a frame resumes in place with a blocking
    /// receive), so total in-flight chunks per machine stay bounded by
    /// `2 × max_live_chunks + workers × (task_split_levels ×
    /// task_split_width + program depth)`.
    pub max_live_chunks: usize,
    /// The message-passing comm subsystem's knobs (in-flight request
    /// window, physical aggregation threshold, synchronous escape hatch).
    /// Every setting reports bitwise-identical counts/traffic/virtual
    /// time — see [`crate::comm`] and `tests/comm_equivalence.rs`.
    pub comm: CommConfig,
    /// Data-parallel intersection kernels ([`crate::exec::simd`]). `true`
    /// uses the vector tier wherever the host supports it (AVX2, probed
    /// at runtime; scalar fallback elsewhere, and the `KUDU_NO_SIMD`
    /// environment hatch force-disables process-wide); `false` pins the
    /// scalar tier. Wall-clock only: counts, traffic, and virtual time
    /// are bitwise identical either way (`tests/sched_determinism.rs`).
    pub simd: bool,
    /// Graph storage tier (see [`StorageTier`]). `Compact` mines over
    /// block-compressed adjacency with pooled per-frame decode scratch;
    /// the `KUDU_NO_COMPACT` env hatch force-pins CSR process-wide.
    /// Footprint/wall-clock only: every reported bit is tier-invariant.
    pub storage: StorageTier,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            chunk_capacity: 1024,
            mini_batch: 64,
            vertical_sharing: true,
            horizontal_sharing: true,
            cache_frac: 0.10,
            cache_degree_threshold: 16,
            sockets: 1,
            numa_aware: true,
            threads: 1,
            sim_threads: env_knob("KUDU_SIM_THREADS", 0),
            workers_per_machine: env_knob("KUDU_WORKERS_PER_MACHINE", 0),
            task_split_levels: 1,
            task_split_width: 8,
            max_live_chunks: 64,
            comm: CommConfig::default(),
            simd: true,
            storage: StorageTier::default(),
        }
    }
}

impl EngineConfig {
    /// Reject degenerate configurations with a descriptive error instead
    /// of a panic (or hang) deep inside the engine. Called by the session
    /// job builder and the engine entry points.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.chunk_capacity == 0 {
            return Err(ConfigError::ZeroChunkCapacity);
        }
        if self.mini_batch == 0 {
            return Err(ConfigError::ZeroMiniBatch);
        }
        if self.sockets == 0 {
            return Err(ConfigError::ZeroSockets);
        }
        if self.comm.max_in_flight == 0 {
            return Err(ConfigError::ZeroInFlight);
        }
        Ok(())
    }
}

/// Full run configuration: cluster shape + engine + cost models.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub num_machines: usize,
    pub engine: EngineConfig,
    pub net: NetModel,
    pub compute: ComputeModel,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            num_machines: 8,
            engine: EngineConfig::default(),
            net: NetModel::default(),
            compute: ComputeModel::default(),
        }
    }
}

impl RunConfig {
    pub fn single_machine() -> Self {
        RunConfig { num_machines: 1, ..Default::default() }
    }

    pub fn with_machines(n: usize) -> Self {
        RunConfig { num_machines: n, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.num_machines, 8);
        assert!(c.engine.vertical_sharing && c.engine.horizontal_sharing);
        assert!(c.engine.cache_frac > 0.0);
        // Host-parallelism defaults come from the environment so the CI
        // determinism matrix can pin them; unset they mean "all cores".
        // (Assert the real values rather than re-evaluating env_knob —
        // that comparison would be tautological.)
        match std::env::var("KUDU_SIM_THREADS") {
            Err(_) => assert_eq!(c.engine.sim_threads, 0, "default = all available cores"),
            Ok(v) => assert_eq!(c.engine.sim_threads, v.parse::<usize>().unwrap_or(0)),
        }
        match std::env::var("KUDU_WORKERS_PER_MACHINE") {
            Err(_) => assert_eq!(c.engine.workers_per_machine, 0, "default = all available cores"),
            Ok(v) => assert_eq!(c.engine.workers_per_machine, v.parse::<usize>().unwrap_or(0)),
        }
        assert!(c.engine.task_split_width >= 1);
        assert!(c.engine.max_live_chunks >= 1);
        // SIMD defaults on; the env hatch acts inside Kernel::auto, not
        // here, so it also covers paths that bypass the config.
        assert!(c.engine.simd);
        // Storage defaults to CSR unless the CI matrix pins the compact
        // tier via the environment; KUDU_NO_COMPACT wins over both.
        if std::env::var("KUDU_COMPACT_GRAPH").is_err() {
            assert_eq!(c.engine.storage, StorageTier::Csr, "default = CSR tier");
        } else {
            assert_eq!(c.engine.storage, StorageTier::Compact);
        }
        if std::env::var("KUDU_NO_COMPACT").is_ok() {
            assert_eq!(StorageTier::Compact.resolve(), StorageTier::Csr);
        } else {
            assert_eq!(StorageTier::Compact.resolve(), StorageTier::Compact);
        }
        assert_eq!(StorageTier::Csr.resolve(), StorageTier::Csr);
        // Comm defaults: a real in-flight window and, unless the env pins
        // the escape hatch (the CI determinism matrix sets
        // KUDU_SYNC_FETCH=1), the async message-passing path.
        assert!(c.engine.comm.max_in_flight >= 1);
        if std::env::var("KUDU_SYNC_FETCH").is_err() {
            assert!(!c.engine.comm.sync_fetch, "default = async comm");
        }
        assert_eq!(RunConfig::single_machine().num_machines, 1);
        assert_eq!(RunConfig::with_machines(4).num_machines, 4);
        assert!(c.engine.validate().is_ok());
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        let ok = EngineConfig::default();
        assert!(ok.validate().is_ok());
        let bad_cap = EngineConfig { chunk_capacity: 0, ..Default::default() };
        assert_eq!(bad_cap.validate(), Err(ConfigError::ZeroChunkCapacity));
        let bad_mb = EngineConfig { mini_batch: 0, ..Default::default() };
        assert_eq!(bad_mb.validate(), Err(ConfigError::ZeroMiniBatch));
        let bad_sockets = EngineConfig { sockets: 0, ..Default::default() };
        assert_eq!(bad_sockets.validate(), Err(ConfigError::ZeroSockets));
        let bad_window = EngineConfig {
            comm: CommConfig { max_in_flight: 0, ..Default::default() },
            ..Default::default()
        };
        assert_eq!(bad_window.validate(), Err(ConfigError::ZeroInFlight));
        // Errors render as actionable messages.
        let msg = ConfigError::ZeroChunkCapacity.to_string();
        assert!(msg.contains("chunk_capacity"));
        assert!(ConfigError::ZeroInFlight.to_string().contains("max_in_flight"));
    }
}
