//! Run configuration: which engine features are on, cluster shape, chunk
//! sizes — everything the ablation tables toggle.

use crate::metrics::{ComputeModel, NetModel};

/// Kudu engine feature toggles and sizing (paper §5–§6 knobs).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Chunk capacity: number of extendable embeddings per level chunk
    /// (the paper pre-allocates ~1 GB per level; we size by count).
    pub chunk_capacity: usize,
    /// Mini-batch size for work distribution (paper §7: 64).
    pub mini_batch: usize,
    /// Vertical computation sharing (paper §6.1 / Fig 13).
    pub vertical_sharing: bool,
    /// Horizontal data sharing (paper §6.2 / Fig 14).
    pub horizontal_sharing: bool,
    /// Static cache size as a fraction of graph CSR bytes (paper §6.3:
    /// 5–10%); `0.0` disables the cache (Table 6 "no cache").
    pub cache_frac: f64,
    /// Degree threshold for cache insertion (the paper uses 64 at
    /// billion-edge scale; scaled to 16 for the laptop-scale stand-ins so
    /// the cached set covers the same fraction of traffic).
    pub cache_degree_threshold: usize,
    /// NUMA sockets per machine; `1` disables NUMA modelling.
    pub sockets: usize,
    /// NUMA-aware exploration (Table 7); irrelevant when `sockets == 1`.
    pub numa_aware: bool,
    /// Computation threads per machine (virtual; Fig 17). This is part of
    /// the *cost model* — it scales virtual compute time.
    pub threads: usize,
    /// Host threads used to execute the simulation itself (thread-per-
    /// machine, plus root-vertex sharding when only one machine is
    /// simulated). `0` = all available cores. Changes wall-clock time
    /// only: counts, traffic, and virtual-time metrics are byte-for-byte
    /// identical for every value.
    pub sim_threads: usize,
    /// Number of contiguous root-vertex shards a single simulated
    /// machine's start range is split into, so the single-machine and
    /// NUMA configurations can also use the host cores. Fixed by config —
    /// never derived from `sim_threads` — which is what keeps results
    /// independent of the host thread count.
    pub root_shards: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            chunk_capacity: 1024,
            mini_batch: 64,
            vertical_sharing: true,
            horizontal_sharing: true,
            cache_frac: 0.10,
            cache_degree_threshold: 16,
            sockets: 1,
            numa_aware: true,
            threads: 1,
            sim_threads: 0,
            root_shards: 8,
        }
    }
}

/// Full run configuration: cluster shape + engine + cost models.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub num_machines: usize,
    pub engine: EngineConfig,
    pub net: NetModel,
    pub compute: ComputeModel,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            num_machines: 8,
            engine: EngineConfig::default(),
            net: NetModel::default(),
            compute: ComputeModel::default(),
        }
    }
}

impl RunConfig {
    pub fn single_machine() -> Self {
        RunConfig { num_machines: 1, ..Default::default() }
    }

    pub fn with_machines(n: usize) -> Self {
        RunConfig { num_machines: n, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.num_machines, 8);
        assert!(c.engine.vertical_sharing && c.engine.horizontal_sharing);
        assert!(c.engine.cache_frac > 0.0);
        assert_eq!(c.engine.sim_threads, 0, "default = all available cores");
        assert!(c.engine.root_shards >= 1);
        assert_eq!(RunConfig::single_machine().num_machines, 1);
        assert_eq!(RunConfig::with_machines(4).num_machines, 4);
    }
}
