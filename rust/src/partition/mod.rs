//! 1-D graph partitioning (paper §3.1).
//!
//! The vertex set is hash-partitioned into N parts; machine i holds all
//! edges with at least one endpoint in V_i (so every owned vertex's full
//! adjacency list is local). Partitioning is what lets Kudu scale memory —
//! the table-5 harness uses [`PartitionedGraph::partition_bytes`] against a
//! per-machine budget to demonstrate the replication gate.

use crate::graph::{Graph, GraphStore, VertexId};

/// Hash-based vertex → machine mapping. The paper uses a hash function for
/// balanced distribution; we use a multiplicative hash (plain modulo would
/// correlate with generator vertex ids).
#[derive(Clone, Copy, Debug)]
pub struct PartitionMap {
    num_machines: usize,
}

impl PartitionMap {
    pub fn new(num_machines: usize) -> Self {
        assert!(num_machines >= 1);
        PartitionMap { num_machines }
    }

    #[inline]
    pub fn num_machines(&self) -> usize {
        self.num_machines
    }

    /// Owner machine of vertex `v`.
    #[inline]
    pub fn owner(&self, v: VertexId) -> usize {
        // Fibonacci hashing, reduced to [0, N).
        let h = (v as u64).wrapping_mul(0x9E3779B97F4A7C15);
        ((h >> 32) as usize * self.num_machines) >> 32
    }

    /// Route a batch of undirected edges to owning machines: each edge is
    /// delivered to the owner of *both* endpoints (once when they agree),
    /// mirroring the 1-D invariant that machine i stores every edge with
    /// ≥1 endpoint in V_i. Returns one per-machine list; within each list
    /// edges keep batch order, so routing is deterministic and the
    /// per-machine ingest replay order is fixed by the batch alone.
    pub fn route_edges(&self, edges: &[(VertexId, VertexId)]) -> Vec<Vec<(VertexId, VertexId)>> {
        let mut out = vec![Vec::new(); self.num_machines];
        for &(u, v) in edges {
            let mu = self.owner(u);
            let mv = self.owner(v);
            out[mu].push((u, v));
            if mv != mu {
                out[mv].push((u, v));
            }
        }
        out
    }
}

/// A 1-D partitioned graph: the shared storage tier plus the ownership
/// map.
///
/// In the simulated cluster all partitions live in one address space; the
/// *policy* distinction between local and remote is made by
/// [`PartitionedGraph::is_local`], and every remote access is routed
/// through the accounted transport in [`crate::cluster`].
///
/// The graph is held behind the [`GraphStore`] seam, so partitions work
/// identically over `Vec`-CSR and compact storage. All accounting here is
/// degree-based (never decodes), and `partition_bytes` reports *logical*
/// CSR bytes in both tiers — byte-denominated decisions downstream stay
/// bitwise tier-invariant.
#[derive(Clone, Copy)]
pub struct PartitionedGraph<'g> {
    pub store: GraphStore<'g>,
    pub map: PartitionMap,
}

impl<'g> PartitionedGraph<'g> {
    pub fn new(graph: &'g Graph, num_machines: usize) -> Self {
        Self::from_store(GraphStore::Csr(graph), num_machines)
    }

    pub fn from_store(store: GraphStore<'g>, num_machines: usize) -> Self {
        PartitionedGraph { store, map: PartitionMap::new(num_machines) }
    }

    #[inline]
    pub fn owner(&self, v: VertexId) -> usize {
        self.map.owner(v)
    }

    #[inline]
    pub fn is_local(&self, machine: usize, v: VertexId) -> bool {
        self.map.owner(v) == machine
    }

    /// Vertices owned by `machine` (the start vertices of its embedding
    /// trees).
    pub fn owned_vertices(&self, machine: usize) -> Vec<VertexId> {
        (0..self.store.num_vertices() as VertexId)
            .filter(|&v| self.owner(v) == machine)
            .collect()
    }

    /// Logical CSR bytes held by `machine`: offsets + adjacency of owned
    /// vertices (each edge with ≥1 endpoint in V_i is stored on machine i,
    /// per the paper's O(|V|/p + |E|/p) representation). Tier-invariant by
    /// construction — the compact tier's physical savings are reported via
    /// `RunStats::bytes_per_edge`, not here.
    pub fn partition_bytes(&self, machine: usize) -> usize {
        let mut edges = 0usize;
        let mut verts = 0usize;
        for v in 0..self.store.num_vertices() as VertexId {
            if self.owner(v) == machine {
                verts += 1;
                edges += self.store.degree(v);
            }
        }
        verts * std::mem::size_of::<u64>() + edges * std::mem::size_of::<VertexId>()
    }

    /// Max over machines of partition size — the per-machine memory
    /// requirement under partitioning.
    pub fn max_partition_bytes(&self) -> usize {
        (0..self.map.num_machines()).map(|m| self.partition_bytes(m)).max().unwrap_or(0)
    }

    /// Load-balance factor: max partition bytes / mean partition bytes.
    pub fn balance_factor(&self) -> f64 {
        let sizes: Vec<usize> =
            (0..self.map.num_machines()).map(|m| self.partition_bytes(m)).collect();
        let max = *sizes.iter().max().unwrap() as f64;
        let mean = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn owner_in_range_and_stable() {
        let map = PartitionMap::new(8);
        for v in 0..10_000u32 {
            let o = map.owner(v);
            assert!(o < 8);
            assert_eq!(o, map.owner(v));
        }
    }

    #[test]
    fn single_machine_owns_all() {
        let map = PartitionMap::new(1);
        for v in 0..100u32 {
            assert_eq!(map.owner(v), 0);
        }
    }

    #[test]
    fn partition_covers_all_vertices() {
        let g = gen::erdos_renyi(500, 1500, 5);
        let pg = PartitionedGraph::new(&g, 4);
        let total: usize = (0..4).map(|m| pg.owned_vertices(m).len()).sum();
        assert_eq!(total, g.num_vertices());
    }

    #[test]
    fn partitions_reasonably_balanced() {
        let g = gen::rmat(12, 8, 7);
        let pg = PartitionedGraph::new(&g, 8);
        // Hash partitioning of a skewed graph is still vertex-balanced;
        // byte balance is looser but bounded.
        assert!(pg.balance_factor() < 3.0, "balance {}", pg.balance_factor());
    }

    #[test]
    fn partition_accounting_is_tier_invariant() {
        let g = gen::rmat(9, 8, 11);
        let c = crate::graph::CompactGraph::from_graph(&g);
        let pg = PartitionedGraph::new(&g, 4);
        let pc = PartitionedGraph::from_store(GraphStore::Compact(&c), 4);
        for m in 0..4 {
            assert_eq!(pg.owned_vertices(m), pc.owned_vertices(m));
            assert_eq!(pg.partition_bytes(m), pc.partition_bytes(m));
        }
        assert_eq!(pg.max_partition_bytes(), pc.max_partition_bytes());
        assert_eq!(pg.balance_factor(), pc.balance_factor());
    }

    #[test]
    fn route_edges_covers_batch_and_respects_ownership() {
        let map = PartitionMap::new(4);
        let batch: Vec<(u32, u32)> = vec![(0, 1), (2, 9), (5, 5), (7, 31), (0, 1)];
        let routed = map.route_edges(&batch);
        assert_eq!(routed.len(), 4);
        let mut delivered = 0usize;
        for (m, list) in routed.iter().enumerate() {
            for &(u, v) in list {
                assert!(map.owner(u) == m || map.owner(v) == m);
            }
            delivered += list.len();
        }
        // Every edge lands on 1 machine (endpoints co-owned) or 2.
        let owners: usize = batch
            .iter()
            .map(|&(u, v)| if map.owner(u) == map.owner(v) { 1 } else { 2 })
            .sum();
        assert_eq!(delivered, owners);
        // Per-machine order follows batch order: the duplicate (0,1) edge
        // appears after the first copy on its owner machines.
        let m0 = map.owner(0);
        let count01 = routed[m0].iter().filter(|&&e| e == (0, 1)).count();
        assert_eq!(count01, 2);
    }

    #[test]
    fn partition_bytes_sum_versus_csr() {
        let g = gen::erdos_renyi(300, 1000, 9);
        let pg = PartitionedGraph::new(&g, 4);
        let sum: usize = (0..4).map(|m| pg.partition_bytes(m)).sum();
        // Partitioned total ≈ whole CSR (each arc stored once at its
        // source vertex's owner; offsets slightly undercounted).
        assert!(sum <= g.csr_bytes());
        assert!(sum >= g.csr_bytes() / 2);
    }
}
