//! A miniature explicit-state model checker for the runtime's lock-free
//! protocols: exhaustive depth-first exploration of **every
//! interleaving** of a small set of model threads, each advancing
//! through *guarded atomic steps* against the real protocol types
//! ([`crate::engine::backpressure::ChunkGate`],
//! [`crate::comm::window::InFlightWindow`],
//! [`crate::comm::window::StopFlag`]).
//!
//! The container image carries no external crates, so this fills the
//! role the `loom` crate would otherwise play for the two CAS protocols
//! the scheduler and the comm fabric are built on — `tests/loom_models.rs`
//! holds the models, and the CI loom leg (`RUSTFLAGS="--cfg loom"`)
//! widens them to larger configurations.
//!
//! ## What a "step" is, and why this is sound
//!
//! A [`Model`] describes each thread as a little program over a shared
//! state: [`Model::enabled`] says whether the thread may take its next
//! step (a *pure* check — loads only, no writes), and [`Model::step`]
//! executes that step. Each step wraps **one whole lock-free operation**
//! of the protocol under test (e.g. one `ChunkGate::try_admit`, one
//! `InFlightWindow::complete`). Those operations are single-location
//! read-modify-write loops, which are linearizable: in any real
//! execution each call takes effect atomically at its linearization
//! point (the successful CAS, or the bound-check load that returns
//! `false`). Exploring every *order* of these linearization points is
//! therefore exactly exploring every observable behaviour of the
//! protocol at sequential consistency.
//!
//! **Limits.** The explorer executes steps sequentially, so it checks
//! the protocols under sequential consistency, not under the weak
//! orderings the code actually compiles to. That is the right tool for
//! the properties checked here — bounds and deadlock-freedom of
//! single-location protocols, which are ordering-independent (an RMW
//! always observes the latest value in the location's modification
//! order, whatever its `Ordering`). The cross-location visibility
//! choices are justified separately, entry by entry, in
//! `tools/audit/atomics.toml`, and exercised for data races by the CI
//! ThreadSanitizer leg.
//!
//! ## Mechanics
//!
//! Atomics cannot be snapshotted and restored, so the explorer replays:
//! every explored prefix is re-executed from a fresh
//! [`Model::make_shared`] state before extending it by one step. Cost
//! is O(depth) per visited state — fine at model scale (tens of steps).
//! Guards keep the exploration *fair by construction*: a thread that
//! would spin (e.g. a requester facing a full window) is simply not
//! enabled, so the explorer never wastes schedules on unbounded retry
//! loops, and a state where some thread is unfinished but **no** thread
//! is enabled is reported as a deadlock — the liveness half of every
//! model.
//!
//! [`Model::invariant`] runs after every step of every schedule (every
//! reachable state is the end of some explored prefix);
//! [`Model::finale`] runs at the end of every complete schedule.

/// Per-thread program counter plus one scratch register, enough to
/// express the step machines of the protocol models.
#[derive(Clone, Copy, Debug, Default)]
pub struct ThreadState {
    /// Position in the thread's step program.
    pub pc: u32,
    /// Model-defined scratch (e.g. "how many of my tasks were admitted").
    pub acc: u64,
}

/// What one [`Model::step`] call did.
pub enum StepOutcome {
    /// The thread took a step and has more to do.
    Ran,
    /// The thread took its final step and is finished.
    Done,
}

/// A small concurrent protocol: `num_threads` step programs over a
/// shared state, explored exhaustively by [`explore`].
pub trait Model {
    /// The shared state the threads race on (holds the real protocol
    /// types under test).
    type Shared;

    /// Fresh shared state for one schedule (called once per replay).
    fn make_shared(&self) -> Self::Shared;

    /// Number of model threads.
    fn num_threads(&self) -> usize;

    /// May thread `t` take its next step now? Must be **pure** (loads
    /// only): the explorer calls it to build frontiers, not to make
    /// progress. A blocked thread stays schedulable later — returning
    /// `false` here models "would spin / would wait", and the explorer
    /// flags a deadlock if no thread is enabled while some are
    /// unfinished.
    fn enabled(&self, shared: &Self::Shared, t: usize, st: &ThreadState) -> bool;

    /// Execute thread `t`'s next step — exactly one linearizable
    /// protocol operation (plus local bookkeeping in `st`).
    fn step(&self, shared: &Self::Shared, t: usize, st: &mut ThreadState) -> StepOutcome;

    /// Safety property, asserted in every reachable state.
    fn invariant(&self, _shared: &Self::Shared) {}

    /// End-state property, asserted after every complete schedule.
    fn finale(&self, _shared: &Self::Shared) {}
}

/// Exploration statistics, mostly so tests can pin that a model is as
/// big as intended (a model that collapses to one schedule checks
/// nothing).
pub struct Explored {
    /// Complete schedules (maximal interleavings) explored.
    pub schedules: u64,
    /// Distinct prefix states visited (including the empty prefix).
    pub states: u64,
}

/// Exhaustively explore every interleaving of `m`'s threads, panicking
/// on any violated invariant, failed finale, or deadlock.
pub fn explore<M: Model>(m: &M) -> Explored {
    let mut stats = Explored { schedules: 0, states: 0 };
    let mut prefix: Vec<usize> = Vec::new();
    dfs(m, &mut prefix, &mut stats);
    stats
}

fn dfs<M: Model>(m: &M, prefix: &mut Vec<usize>, stats: &mut Explored) {
    let n = m.num_threads();
    // Replay the prefix on fresh shared state (atomics cannot be
    // snapshotted, so each branch re-executes its history).
    let shared = m.make_shared();
    let mut states: Vec<ThreadState> = (0..n).map(|_| ThreadState::default()).collect();
    let mut done = vec![false; n];
    for &t in prefix.iter() {
        debug_assert!(!done[t], "scheduled a finished thread");
        if let StepOutcome::Done = m.step(&shared, t, &mut states[t]) {
            done[t] = true;
        }
    }
    stats.states += 1;
    m.invariant(&shared);

    let mut extended = false;
    let mut blocked = false;
    for t in 0..n {
        if done[t] {
            continue;
        }
        if m.enabled(&shared, t, &states[t]) {
            extended = true;
            prefix.push(t);
            dfs(m, prefix, stats);
            prefix.pop();
        } else {
            blocked = true;
        }
    }
    if !extended {
        assert!(
            !blocked,
            "deadlock: unfinished thread(s) with no enabled step after schedule {prefix:?}"
        );
        m.finale(&shared);
        stats.schedules += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Two threads, two unguarded increments each: the explorer must
    /// see all 4!/(2!·2!) = 6 interleavings and a total of 4 in every
    /// finale.
    struct Counter;

    impl Model for Counter {
        type Shared = AtomicU64;

        fn make_shared(&self) -> AtomicU64 {
            AtomicU64::new(0)
        }

        fn num_threads(&self) -> usize {
            2
        }

        fn enabled(&self, _s: &AtomicU64, _t: usize, _st: &ThreadState) -> bool {
            true
        }

        fn step(&self, s: &AtomicU64, _t: usize, st: &mut ThreadState) -> StepOutcome {
            s.fetch_add(1, Ordering::Relaxed);
            st.pc += 1;
            if st.pc == 2 {
                StepOutcome::Done
            } else {
                StepOutcome::Ran
            }
        }

        fn invariant(&self, s: &AtomicU64) {
            assert!(s.load(Ordering::Relaxed) <= 4);
        }

        fn finale(&self, s: &AtomicU64) {
            assert_eq!(s.load(Ordering::Relaxed), 4);
        }
    }

    #[test]
    fn counter_explores_all_interleavings() {
        let stats = explore(&Counter);
        assert_eq!(stats.schedules, 6);
        assert!(stats.states > 6);
    }

    /// Producer sets a flag and finishes; consumer is guarded on the
    /// flag. The guard serialises the schedule: exactly one exists, and
    /// no deadlock is reported because the producer is always enabled.
    struct Handoff;

    impl Model for Handoff {
        type Shared = AtomicU64;

        fn make_shared(&self) -> AtomicU64 {
            AtomicU64::new(0)
        }

        fn num_threads(&self) -> usize {
            2
        }

        fn enabled(&self, s: &AtomicU64, t: usize, _st: &ThreadState) -> bool {
            t == 0 || s.load(Ordering::Relaxed) == 1
        }

        fn step(&self, s: &AtomicU64, t: usize, _st: &mut ThreadState) -> StepOutcome {
            if t == 0 {
                s.store(1, Ordering::Relaxed);
            } else {
                s.store(2, Ordering::Relaxed);
            }
            StepOutcome::Done
        }

        fn finale(&self, s: &AtomicU64) {
            assert_eq!(s.load(Ordering::Relaxed), 2);
        }
    }

    #[test]
    fn guards_serialize_without_deadlock() {
        let stats = explore(&Handoff);
        assert_eq!(stats.schedules, 1);
    }

    /// Two threads each guarded on the other's flag, which nobody ever
    /// sets: the explorer must report the deadlock.
    struct Stuck;

    impl Model for Stuck {
        type Shared = ();

        fn make_shared(&self) {}

        fn num_threads(&self) -> usize {
            2
        }

        fn enabled(&self, _s: &(), _t: usize, _st: &ThreadState) -> bool {
            false
        }

        fn step(&self, _s: &(), _t: usize, _st: &mut ThreadState) -> StepOutcome {
            unreachable!("never enabled")
        }
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn mutual_blocking_is_reported() {
        explore(&Stuck);
    }
}
