//! Deterministic fork-join execution of independent simulation units.
//!
//! The simulated cluster's machines (and a lone machine's root-vertex
//! shards) are mutually independent: each reads the shared graph through a
//! [`crate::cluster::ClusterView`] and writes only its own state. This
//! module runs those units on scoped host threads with a work-stealing
//! index counter and returns results **in unit order**, so every reduction
//! over them is performed in a fixed sequence — results are byte-for-byte
//! identical for any thread count, including 1.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a host-parallelism knob: `0` means "all available cores".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Run `f(i)` for every `i in 0..units` on up to `threads` scoped worker
/// threads and return the outputs in index order. Workers steal unit
/// indices from a shared atomic counter, so a straggler unit never idles
/// the other cores. `f` must be pure with respect to shared state (it may
/// only mutate what it owns); under that contract the output is identical
/// for every `threads` value.
pub fn run_indexed<T, F>(threads: usize, units: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if units == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(units);
    if threads == 1 {
        return (0..units).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..units).map(|_| Mutex::new(None)).collect();
    let f = &f;
    let next = &next;
    let slots = &slots;
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= units {
                    break;
                }
                let result = f(i);
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .iter()
        .map(|slot| slot.lock().unwrap().take().expect("worker completed every claimed unit"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolves_zero_to_cores() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn outputs_in_unit_order() {
        for threads in [1usize, 2, 4, 16] {
            let out = run_indexed(threads, 37, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single() {
        assert!(run_indexed(4, 0, |i| i).is_empty());
        assert_eq!(run_indexed(4, 1, |i| i + 10), vec![10]);
    }

    #[test]
    fn more_threads_than_units() {
        let out = run_indexed(64, 3, |i| i as u64);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn identical_across_thread_counts() {
        // The whole point: a fold over the outputs is thread-count-proof.
        let reference: f64 = run_indexed(1, 100, |i| (i as f64).sqrt()).iter().sum();
        for threads in [2usize, 3, 8] {
            let sum: f64 = run_indexed(threads, 100, |i| (i as f64).sqrt()).iter().sum();
            assert_eq!(sum.to_bits(), reference.to_bits(), "threads={threads}");
        }
    }
}
