//! Deterministic fork-join execution of simulation units.
//!
//! Two primitives, both with the same contract — **host thread count is
//! invisible in the results**:
//!
//! * [`run_indexed`] — independent units, one closure call per unit,
//!   outputs returned in unit order (the baselines' thread-per-machine
//!   path).
//! * [`run_unit_workers`] — the two-level machine × worker pool behind
//!   the fine-grained task scheduler: every unit (simulated machine)
//!   exposes `workers_per_unit` logical workers that cooperate on the
//!   unit's shared state (deques, counters); the pool multiplexes all
//!   `units × workers_per_unit` logical workers onto at most `threads`
//!   host threads, claiming `(unit, slot)` pairs unit-major from one
//!   atomic counter. Cooperation is data-race-free because the unit
//!   state is `Sync`; determinism is the *caller's* contract — unit
//!   state must reduce its outcomes in an order fixed by the work
//!   itself (e.g. task ids), never by claim or completion order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a host-parallelism knob: `0` means "all available cores".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Run the logical workers of `units.len()` units on up to `threads`
/// scoped host threads: `worker(&units[u], slot)` is called exactly once
/// for every `(u, slot)` pair with `slot < workers_per_unit`. Pairs are
/// claimed unit-major, so all of a unit's workers are live together and
/// a lone unit still uses every host thread. A worker for a finished
/// unit must return promptly (it will be claimed even when the unit's
/// work is already done).
pub fn run_unit_workers<S: Sync>(
    threads: usize,
    workers_per_unit: usize,
    units: &[S],
    worker: impl Fn(&S, usize) + Sync,
) {
    let total = units.len() * workers_per_unit;
    if total == 0 {
        return;
    }
    let threads = threads.max(1).min(total);
    if threads == 1 {
        for u in units {
            for slot in 0..workers_per_unit {
                worker(u, slot);
            }
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let next = &next;
    let worker = &worker;
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(move || loop {
                let p = next.fetch_add(1, Ordering::Relaxed);
                if p >= total {
                    break;
                }
                worker(&units[p / workers_per_unit], p % workers_per_unit);
            });
        }
    });
}

/// Run `f(i)` for every `i in 0..units` on up to `threads` scoped worker
/// threads and return the outputs in index order. `f` must be pure with
/// respect to shared state (it may only mutate what it owns); under that
/// contract the output is identical for every `threads` value. This is
/// the single-worker special case of [`run_unit_workers`].
pub fn run_indexed<T, F>(threads: usize, units: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let slots: Vec<(usize, Mutex<Option<T>>)> =
        (0..units).map(|i| (i, Mutex::new(None))).collect();
    run_unit_workers(threads, 1, &slots, |(i, slot), _| {
        *slot.lock().unwrap() = Some(f(*i));
    });
    slots
        .into_iter()
        .map(|(_, slot)| slot.into_inner().unwrap().expect("worker completed every claimed unit"))
        .collect()
}

// Heavy under Miri (full engine runs / threads / file I/O): the Miri
// leg covers the light per-module tests and the protocol types.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn resolves_zero_to_cores() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn outputs_in_unit_order() {
        for threads in [1usize, 2, 4, 16] {
            let out = run_indexed(threads, 37, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single() {
        assert!(run_indexed(4, 0, |i| i).is_empty());
        assert_eq!(run_indexed(4, 1, |i| i + 10), vec![10]);
    }

    #[test]
    fn more_threads_than_units() {
        let out = run_indexed(64, 3, |i| i as u64);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn identical_across_thread_counts() {
        // The whole point: a fold over the outputs is thread-count-proof.
        let reference: f64 = run_indexed(1, 100, |i| (i as f64).sqrt()).iter().sum();
        for threads in [2usize, 3, 8] {
            let sum: f64 = run_indexed(threads, 100, |i| (i as f64).sqrt()).iter().sum();
            assert_eq!(sum.to_bits(), reference.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn unit_workers_visit_every_slot_once() {
        // units × workers grid, each cell incremented exactly once, for
        // host thread counts below, at, and above the logical total.
        for threads in [1usize, 2, 5, 64] {
            let units: Vec<Vec<AtomicU64>> = (0..5)
                .map(|_| (0..3).map(|_| AtomicU64::new(0)).collect())
                .collect();
            run_unit_workers(threads, 3, &units, |unit, slot| {
                unit[slot].fetch_add(1, Ordering::Relaxed);
            });
            for (u, unit) in units.iter().enumerate() {
                for (s, cell) in unit.iter().enumerate() {
                    assert_eq!(cell.load(Ordering::Relaxed), 1, "threads={threads} u={u} s={s}");
                }
            }
        }
    }

    #[test]
    fn unit_workers_share_unit_state() {
        // Workers of one unit cooperate on shared Sync state; the
        // per-unit sum is worker-count- and thread-count-proof.
        for (threads, wpu) in [(1usize, 4usize), (3, 4), (8, 2), (2, 1)] {
            let units: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
            run_unit_workers(threads, wpu, &units, |unit, slot| {
                unit.fetch_add(slot as u64 + 1, Ordering::Relaxed);
            });
            let expect: u64 = (1..=wpu as u64).sum();
            for u in &units {
                assert_eq!(u.load(Ordering::Relaxed), expect, "threads={threads} wpu={wpu}");
            }
        }
    }

    #[test]
    fn unit_workers_empty_is_noop() {
        let none: Vec<AtomicU64> = Vec::new();
        run_unit_workers(4, 3, &none, |_, _| panic!("no units, no calls"));
        let some = [AtomicU64::new(0)];
        run_unit_workers(4, 0, &some, |_, _| panic!("zero workers, no calls"));
    }
}
