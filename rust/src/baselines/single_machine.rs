//! Single-machine pattern-aware DFS baseline (AutomineIH-style).
//!
//! Direct execution of the plan's nested intersection loops on one machine
//! holding the whole graph — no chunks, no scheduling, no communication.
//! This is the most efficient possible single-thread execution of the same
//! algorithm, which makes it the COST-metric reference (Fig 17) and the
//! Table 4 comparator.

use crate::exec;
use crate::graph::{Graph, VertexId};
use crate::metrics::{ComputeModel, RunStats};
use crate::pattern::MAX_PATTERN;
use crate::plan::{Plan, Source};

/// Single-machine DFS miner.
pub struct SingleMachine;

impl SingleMachine {
    /// Count embeddings of `plan`'s pattern in `g`.
    pub fn run(g: &Graph, plan: &Plan, compute: &ComputeModel) -> RunStats {
        // audit: wall-clock — RunStats::wall_s diagnostic, outside the
        // determinism contract.
        let wall = std::time::Instant::now();
        let mut st = State {
            g,
            plan,
            // Per-level stored sets for vertical sharing, same reuse the
            // compiled Automine loops get from hoisting intersections.
            stored: vec![Vec::new(); plan.depth()],
            scratch: vec![Vec::new(); plan.depth() + 1],
            many: exec::MultiScratch::default(),
            vertices: [0; MAX_PATTERN],
            count: 0,
            work: 0,
        };
        let l0 = plan.pattern.label(0);
        for v in 0..g.num_vertices() as VertexId {
            if l0 != 0 && g.label(v) != l0 {
                continue;
            }
            st.vertices[0] = v;
            st.recurse(1);
        }
        let mut stats = RunStats::default();
        stats.counts = vec![st.count];
        stats.work_units = st.work;
        stats.virtual_time_s = st.work as f64 * compute.seconds_per_unit;
        stats.wall_s = wall.elapsed().as_secs_f64();
        stats
    }
}

struct State<'a> {
    g: &'a Graph,
    plan: &'a Plan,
    stored: Vec<Vec<VertexId>>,
    scratch: Vec<Vec<VertexId>>,
    many: exec::MultiScratch,
    vertices: [VertexId; MAX_PATTERN],
    count: u64,
    work: u64,
}

impl<'a> State<'a> {
    fn recurse(&mut self, level: usize) {
        let depth = self.plan.depth();
        let step = &self.plan.steps[level - 1];

        // Candidate set from plan sources (with vertical sharing via the
        // per-level stored sets).
        let mut cand = std::mem::take(&mut self.scratch[level]);
        {
            // Explicit pushes (not a closure) so the slice borrows stay
            // field-disjoint from the `&mut self.many` scratch below.
            let mut slices: Vec<&[VertexId]> = Vec::with_capacity(step.sources.len());
            for s in &step.sources {
                slices.push(match *s {
                    Source::Adj(j) => self.g.neighbors(self.vertices[j]),
                    Source::Stored(j) => self.stored[j].as_slice(),
                });
            }
            let w = match slices.len() {
                1 => {
                    cand.clear();
                    cand.extend_from_slice(slices[0]);
                    exec::Work(1)
                }
                2 => exec::intersect(slices[0], slices[1], &mut cand),
                _ => exec::intersect_many(slices[0], &slices[1..], &mut cand, &mut self.many),
            };
            self.work += w.0;
        }

        // Vertex-induced exclusions.
        if !step.exclude.is_empty() {
            let mut tmp = std::mem::take(&mut self.scratch[depth]);
            for &j in &step.exclude {
                let w = exec::difference(&cand, self.g.neighbors(self.vertices[j]), &mut tmp);
                self.work += w.0;
                std::mem::swap(&mut cand, &mut tmp);
            }
            self.scratch[depth] = tmp;
        }

        // Restriction window.
        let mut lo: VertexId = 0;
        let mut hi: VertexId = VertexId::MAX;
        for &j in &step.greater_than {
            lo = lo.max(self.vertices[j].saturating_add(1));
        }
        for &j in &step.less_than {
            hi = hi.min(self.vertices[j]);
        }
        let start = cand.partition_point(|&v| v < lo);
        let end = cand.partition_point(|&v| v < hi);

        if level == depth - 1 {
            let mut c = 0u64;
            if step.label == 0 {
                c = (end.max(start) - start) as u64;
                for &u in &self.vertices[..level] {
                    if u >= lo && u < hi && cand[start..end].binary_search(&u).is_ok() {
                        c -= 1;
                    }
                }
            } else {
                for k in start..end {
                    let v = cand[k];
                    if self.g.label(v) == step.label && !self.vertices[..level].contains(&v) {
                        c += 1;
                    }
                }
            }
            self.count += c;
            self.work += (end.max(start) - start) as u64 + 1;
        } else {
            // Save the raw candidate set for descendants if the plan
            // stores it at this level.
            if self.plan.store_set[level] {
                std::mem::swap(&mut self.stored[level], &mut cand);
                // Iterate from the stored copy.
                for k in start..end {
                    let v = self.stored[level][k];
                    if self.vertices[..level].contains(&v)
                        || (step.label != 0 && self.g.label(v) != step.label)
                    {
                        continue;
                    }
                    self.vertices[level] = v;
                    self.recurse(level + 1);
                }
                std::mem::swap(&mut self.stored[level], &mut cand);
            } else {
                for k in start..end {
                    let v = cand[k];
                    if self.vertices[..level].contains(&v)
                        || (step.label != 0 && self.g.label(v) != step.label)
                    {
                        continue;
                    }
                    self.vertices[level] = v;
                    self.recurse(level + 1);
                }
            }
        }
        self.scratch[level] = cand;
    }
}

// Heavy under Miri (full engine runs / threads / file I/O): the Miri
// leg covers the light per-module tests and the protocol types.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::pattern::brute::{count_embeddings, Induced};
    use crate::pattern::Pattern;
    use crate::plan::{automine_plan, graphpi_plan};

    #[test]
    fn matches_oracle_edge_induced() {
        let g = gen::rmat(8, 8, 41);
        for p in [Pattern::triangle(), Pattern::clique(4), Pattern::chain(4), Pattern::cycle(4)] {
            let expect = count_embeddings(&g, &p, Induced::Edge);
            let plan = automine_plan(&p, Induced::Edge);
            let got = SingleMachine::run(&g, &plan, &ComputeModel::default()).total_count();
            assert_eq!(got, expect, "{p:?}");
        }
    }

    #[test]
    fn matches_oracle_vertex_induced() {
        let g = gen::erdos_renyi(70, 250, 43);
        for p in [Pattern::chain(3), Pattern::star(4), Pattern::cycle(4)] {
            let expect = count_embeddings(&g, &p, Induced::Vertex);
            let plan = graphpi_plan(&p, Induced::Vertex);
            let got = SingleMachine::run(&g, &plan, &ComputeModel::default()).total_count();
            assert_eq!(got, expect, "{p:?}");
        }
    }

    #[test]
    fn work_units_accumulate() {
        let g = gen::erdos_renyi(100, 500, 47);
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let st = SingleMachine::run(&g, &plan, &ComputeModel::default());
        assert!(st.work_units > 0);
        assert!(st.virtual_time_s > 0.0);
        assert_eq!(st.network_bytes, 0);
    }
}
