//! Comparator execution models (paper §3.2 and §8 evaluation).
//!
//! Every baseline mines over the same graph/pattern/plan substrates as the
//! Kudu engine, so the tables isolate exactly what the paper credits: task
//! granularity, scheduling, and data-reuse cost.
//!
//! * [`single_machine`] — AutomineIH-style nested-loop DFS on one machine
//!   (also the COST-metric reference, Fig 17).
//! * [`replicated`] — GraphPi-style distributed mining with the graph
//!   replicated on every machine: coarse first-loop parallelism plus a
//!   startup workload-partitioning cost, no communication.
//! * [`gthinker`] — "think like a subgraph" over a partitioned graph:
//!   coarse per-start-vertex tasks that pull their whole working set
//!   through a reference-counted software cache with per-request
//!   management overhead.
//! * [`moving_comp`] — Arabesque-style "moving computation to data":
//!   level-synchronous BFS where partial embeddings are shipped to the
//!   owner of the data they need next.

pub mod gthinker;
pub mod moving_comp;
pub mod replicated;
pub mod single_machine;

pub use gthinker::GThinker;
pub use moving_comp::MovingComputation;
pub use replicated::Replicated;
pub use single_machine::SingleMachine;
