//! Replicated-graph distributed baseline (GraphPi-style, paper Table 3).
//!
//! Every machine holds the whole graph, so there is no mining-time
//! communication — but two structural costs remain, and they are what the
//! paper's Table 3 and Fig 15 expose:
//!
//! 1. **Startup workload partitioning**: GraphPi statically splits the
//!    first loop(s) across machines before mining starts; the paper
//!    attributes its poor small-workload numbers to this startup overhead.
//! 2. **Coarse-grained parallelism**: only the first loop is
//!    parallelised, so per-start-vertex work imbalance is not smoothed by
//!    fine-grained task scheduling — the skewed-graph stragglers behind
//!    GraphPi's sub-linear inter-node scaling (Fig 15).

use crate::graph::{Graph, VertexId};
use crate::metrics::{ComputeModel, RunStats};
use crate::par;
use crate::pattern::MAX_PATTERN;
use crate::plan::Plan;

/// Startup cost (virtual seconds) per machine: workload partitioning +
/// graph broadcast bookkeeping. GraphPi's measured startup dominates
/// sub-second workloads (Table 3: TC on MiCo takes 704 ms replicated vs
/// 35 ms on Kudu). Scaled to this testbed's workload sizes (DESIGN.md §1).
pub const STARTUP_S_PER_MACHINE: f64 = 0.0005;

/// Replicated-graph distributed miner.
pub struct Replicated;

impl Replicated {
    /// Mine with `machines` replicas and `threads` *modeled* compute
    /// threads per machine. Start vertices are block-partitioned
    /// (GraphPi's static first-loop split); virtual time is the slowest
    /// machine (stragglers included) plus startup. `sim_threads` is the
    /// host-side parallelism of the simulation (`0` = all cores) and
    /// never changes results.
    pub fn run(
        g: &Graph,
        plan: &Plan,
        machines: usize,
        threads: usize,
        sim_threads: usize,
        compute: &ComputeModel,
    ) -> RunStats {
        // audit: wall-clock — RunStats::wall_s diagnostic, outside the
        // determinism contract.
        let wall = std::time::Instant::now();
        let n = g.num_vertices() as VertexId;
        let mut total = 0u64;
        let mut total_work = 0u64;
        let mut slowest = 0u64;
        // Static interleaved split of the first loop (GraphPi partitions
        // the first loop(s) with a cost model before mining; round-robin
        // is the closest static approximation). Still coarse-grained: a
        // deep straggler subtree cannot be re-balanced once assigned.
        // Replicas are independent, so each runs on its own host thread;
        // the fold below is in machine order (u64 sums + max), so results
        // never depend on the host thread count.
        let outcomes = par::run_indexed(par::resolve_threads(sim_threads), machines, |m| {
            mine_split(g, plan, m as VertexId, machines as VertexId, n)
        });
        for (count, work) in outcomes {
            total += count;
            total_work += work;
            slowest = slowest.max(work);
        }
        let mut stats = RunStats::default();
        stats.counts = vec![total];
        stats.work_units = total_work;
        // GraphPi parallelises the first loop(s) across the node's cores
        // too; the straggler penalty is already in `slowest`.
        stats.virtual_time_s = slowest as f64 * compute.seconds_per_unit
            / threads.max(1) as f64
            + STARTUP_S_PER_MACHINE * machines as f64;
        // Replication: per-machine memory = whole graph.
        stats.peak_embedding_bytes = g.csr_bytes() as u64;
        stats.wall_s = wall.elapsed().as_secs_f64();
        stats
    }

    /// Per-machine memory requirement under replication (the Table 5
    /// gate: RMAT-500M's 84 GB CSR cannot fit a 64 GB node).
    pub fn memory_required_bytes(g: &Graph) -> usize {
        g.csr_bytes()
    }
}

/// Mine the plan with GraphPi-style static first-loops splitting: machine
/// `m` of `stride` processes the (v0, v1-index) pairs hashed to it (the
/// paper: GraphPi "only parallelizes the first or first few loops ... in a
/// coarse-grained fashion"). Every machine scans the level-0/1 loops (the
/// duplicated coarse work); subtrees below a pair run on one machine only
/// and cannot be re-balanced — the remaining straggler source.
fn mine_split(g: &Graph, plan: &Plan, m: VertexId, stride: VertexId, n: VertexId) -> (u64, u64) {
    use crate::exec;
    use crate::plan::Source;

    struct S<'a> {
        g: &'a Graph,
        plan: &'a Plan,
        stored: Vec<Vec<VertexId>>,
        scratch: Vec<Vec<VertexId>>,
        many: exec::MultiScratch,
        vertices: [VertexId; MAX_PATTERN],
        count: u64,
        work: u64,
        /// (machine, machines): second-loop ownership filter.
        split: (u64, u64),
    }
    impl<'a> S<'a> {
        /// Second-loop split: level-1 subtrees are owned by one machine.
        #[inline]
        fn owns(&self, level: usize, k: usize) -> bool {
            if level != 1 {
                return true;
            }
            let (m, stride) = self.split;
            (self.vertices[0] as u64).wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(k as u64)
                % stride
                == m
        }

        fn recurse(&mut self, level: usize) {
            let depth = self.plan.depth();
            let step = &self.plan.steps[level - 1];
            let mut cand = std::mem::take(&mut self.scratch[level]);
            {
                // Explicit pushes (not a closure) so the slice borrows
                // stay field-disjoint from the `&mut self.many` below.
                let mut slices: Vec<&[VertexId]> = Vec::with_capacity(step.sources.len());
                for s in &step.sources {
                    slices.push(match *s {
                        Source::Adj(j) => self.g.neighbors(self.vertices[j]),
                        Source::Stored(j) => self.stored[j].as_slice(),
                    });
                }
                let w = match slices.len() {
                    1 => {
                        cand.clear();
                        cand.extend_from_slice(slices[0]);
                        exec::Work(1)
                    }
                    2 => exec::intersect(slices[0], slices[1], &mut cand),
                    _ => {
                        exec::intersect_many(slices[0], &slices[1..], &mut cand, &mut self.many)
                    }
                };
                self.work += w.0;
            }
            if !step.exclude.is_empty() {
                let mut tmp = std::mem::take(&mut self.scratch[depth]);
                for &j in &step.exclude {
                    let w =
                        exec::difference(&cand, self.g.neighbors(self.vertices[j]), &mut tmp);
                    self.work += w.0;
                    std::mem::swap(&mut cand, &mut tmp);
                }
                self.scratch[depth] = tmp;
            }
            let mut lo: VertexId = 0;
            let mut hi: VertexId = VertexId::MAX;
            for &j in &step.greater_than {
                lo = lo.max(self.vertices[j].saturating_add(1));
            }
            for &j in &step.less_than {
                hi = hi.min(self.vertices[j]);
            }
            let start = cand.partition_point(|&v| v < lo);
            let end = cand.partition_point(|&v| v < hi);
            if level == depth - 1 {
                if level == 1 {
                    // Depth-2 pattern: the "second loop" is the last level;
                    // honour the pair split during the bulk count.
                    for k in start..end {
                        let v = cand[k];
                        if self.vertices[..level].contains(&v) || !self.owns(level, k) {
                            continue;
                        }
                        self.count += 1;
                    }
                    self.work += (end.max(start) - start) as u64 + 1;
                    self.scratch[level] = cand;
                    return;
                }
                let mut c = (end.max(start) - start) as u64;
                for &u in &self.vertices[..level] {
                    if u >= lo && u < hi && cand[start..end].binary_search(&u).is_ok() {
                        c -= 1;
                    }
                }
                self.count += c;
                self.work += (end.max(start) - start) as u64 + 1;
            } else if self.plan.store_set[level] {
                std::mem::swap(&mut self.stored[level], &mut cand);
                for k in start..end {
                    let v = self.stored[level][k];
                    if self.vertices[..level].contains(&v) || !self.owns(level, k) {
                        continue;
                    }
                    self.vertices[level] = v;
                    self.recurse(level + 1);
                }
                std::mem::swap(&mut self.stored[level], &mut cand);
            } else {
                for k in start..end {
                    let v = cand[k];
                    if self.vertices[..level].contains(&v) || !self.owns(level, k) {
                        continue;
                    }
                    self.vertices[level] = v;
                    self.recurse(level + 1);
                }
            }
            self.scratch[level] = cand;
        }
    }

    let mut s = S {
        g,
        plan,
        stored: vec![Vec::new(); plan.depth()],
        scratch: vec![Vec::new(); plan.depth() + 1],
        many: exec::MultiScratch::default(),
        vertices: [0; MAX_PATTERN],
        count: 0,
        work: 0,
        split: (m as u64, stride as u64),
    };
    // Every machine scans all first-loop vertices (replicated graph); the
    // split applies at the second loop.
    for v in 0..n {
        s.vertices[0] = v;
        s.recurse(1);
    }
    (s.count, s.work)
}

// Heavy under Miri (full engine runs / threads / file I/O): the Miri
// leg covers the light per-module tests and the protocol types.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::pattern::brute::{count_embeddings, Induced};
    use crate::pattern::Pattern;
    use crate::plan::automine_plan;

    #[test]
    fn matches_oracle() {
        let g = gen::rmat(8, 8, 53);
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let expect = count_embeddings(&g, &Pattern::triangle(), Induced::Edge);
        for m in [1, 2, 4, 8] {
            let st = Replicated::run(&g, &plan, m, 1, 0, &ComputeModel::default());
            assert_eq!(st.total_count(), expect, "machines={m}");
        }
    }

    #[test]
    fn startup_cost_grows_with_machines() {
        let g = gen::erdos_renyi(50, 100, 3);
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let t1 = Replicated::run(&g, &plan, 1, 1, 0, &ComputeModel::default()).virtual_time_s;
        let t8 = Replicated::run(&g, &plan, 8, 1, 0, &ComputeModel::default()).virtual_time_s;
        // Tiny workload: startup dominates, so 8 machines are SLOWER —
        // the paper's small-workload observation.
        assert!(t8 > t1);
    }

    #[test]
    fn memory_is_full_graph() {
        let g = gen::erdos_renyi(200, 800, 5);
        assert_eq!(Replicated::memory_required_bytes(&g), g.csr_bytes());
    }

    #[test]
    fn straggler_limits_scaling_on_skewed() {
        // A planted-hub graph: block partitioning puts the hubs (low ids)
        // on machine 0 — classic straggler.
        let g = gen::planted_hubs(4000, 8000, 6, 0.4, 7);
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let c = ComputeModel::default();
        let t1 = Replicated::run(&g, &plan, 1, 1, 0, &c);
        let t8 = Replicated::run(&g, &plan, 8, 1, 0, &c);
        let speedup = t1.virtual_time_s / t8.virtual_time_s;
        assert!(speedup < 7.0, "skewed replicated speedup should be sub-linear, got {speedup}");
    }
}
