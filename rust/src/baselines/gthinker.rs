//! G-thinker-style baseline: "Think Like a Subgraph" over a partitioned
//! graph (paper §3.2, Table 2).
//!
//! One coarse task per start vertex. Each task first *pulls its whole
//! working set* — every edge list the full nested enumeration from that
//! vertex might touch (for the patterns here, the start vertex plus its
//! 1-hop neighbourhood) — then computes entirely locally. Data reuse goes
//! through a reference-counted software cache whose per-request management
//! cost is charged explicitly; that overhead, not bandwidth, is what makes
//! G-thinker catastrophically slow on low-skew graphs like Patents
//! (Table 2's 1289.8× gap): each request touches a tiny edge list, so the
//! cache bookkeeping cannot be amortised.

use crate::cluster::{Timeline, TrafficLedger, Transport};
use crate::comm::{CommConfig, CommFabric, ResponseSlot, ShutdownGuard};
use crate::graph::{Graph, VertexId};
use crate::metrics::{ComputeModel, RunStats};
use crate::par;
use crate::plan::Plan;
use std::collections::{BTreeMap, HashMap};

/// Software-cache management cost per request, in work units. Covers hash
/// lookup, reference-count update, lock, and GC amortisation — the "high
/// overhead" mechanisms of §3.2/§6.3.
pub const CACHE_REQUEST_OVERHEAD_UNITS: u64 = 400;
/// Additional per-task setup/teardown (task objects are heap-allocated,
/// queued, possibly spilled to disk in G-thinker).
pub const TASK_OVERHEAD_UNITS: u64 = 2_000;

/// G-thinker-like distributed miner.
pub struct GThinker;

impl GThinker {
    /// Runs over the same split transport as the Kudu engine (shared
    /// read-only [`crate::cluster::ClusterView`], one [`TrafficLedger`]
    /// per machine, merged after the join), one host thread per machine —
    /// so Table 2/3 wall-clock comparisons stay apples-to-apples.
    /// `threads` is the *modeled* per-machine thread count (scales
    /// virtual time); `sim_threads` is the host-side parallelism of the
    /// simulation itself (`0` = all cores), which never changes results:
    /// machines only read shared state, and the reduction below runs in
    /// machine order. `comm` selects the fetch transport: the real
    /// message-passing fabric of [`crate::comm`] (a per-task pull becomes
    /// batched `FetchRequest`s answered by the owner's comm thread, with
    /// the per-list copy work charged from the received payloads), or
    /// the synchronous shared-view path when `comm.sync_fetch` is set —
    /// bitwise-identical metrics either way.
    pub fn run(
        g: &Graph,
        plan: &Plan,
        threads: usize,
        sim_threads: usize,
        comm: &CommConfig,
        compute: &ComputeModel,
        transport: &mut Transport,
    ) -> RunStats {
        // audit: wall-clock — RunStats::wall_s diagnostic, outside the
        // determinism contract.
        let wall = std::time::Instant::now();
        let spu = compute.seconds_per_unit / threads.max(1) as f64;
        let n = transport.num_machines();
        let view = transport.view();
        let fabric = (n > 1 && !comm.sync_fetch).then(|| CommFabric::new(n, *comm));

        let outcomes = std::thread::scope(|scope| {
            if let Some(f) = &fabric {
                for m in 0..n {
                    scope.spawn(move || f.run_server(m, g));
                }
            }
            let fab = fabric.as_ref();
            // Stop the servers when the machines finish (or a panic
            // unwinds past us) so the scope's join always completes.
            let _shutdown = ShutdownGuard(fab);
            par::run_indexed(par::resolve_threads(sim_threads), n, |machine| {
            let mut timeline = Timeline::default();
            let mut work = 0u64;
            let mut ledger = TrafficLedger::new(n);
            // Ref-counted software cache: vertex -> refcount. Capacity is
            // generous (G-thinker caches aggressively); the cost is the
            // per-request management, not misses.
            let mut cache: HashMap<VertexId, u32> = HashMap::new();
            let starts = view.partitioned().owned_vertices(machine);
            let mut count = 0u64;

            for &v0 in &starts {
                work += TASK_OVERHEAD_UNITS;
                // Working set: v0 and its full 1-hop neighbourhood ("users
                // specify the subgraph, e.g. the start vertex and its
                // 1-hop neighbours"). Coarse: fetched whether or not the
                // enumeration will use each list (paper: "not all data in
                // the subgraph are used ... some communication is wasted").
                let mut to_fetch: Vec<VertexId> = Vec::with_capacity(g.degree(v0) + 1);
                for &u in std::iter::once(&v0).chain(g.neighbors(v0)) {
                    work += CACHE_REQUEST_OVERHEAD_UNITS;
                    match cache.get_mut(&u) {
                        Some(rc) => *rc += 1,
                        None => {
                            cache.insert(u, 1);
                            if view.partitioned().owner(u) != machine {
                                to_fetch.push(u);
                            }
                        }
                    }
                }
                // One batched pull per remote machine for this task.
                // BTreeMap: owner iteration order is part of the virtual
                // timeline, so it must be deterministic.
                let mut by_owner: BTreeMap<usize, Vec<VertexId>> = BTreeMap::new();
                for u in to_fetch {
                    by_owner.entry(view.partitioned().owner(u)).or_default().push(u);
                }
                let mut gate = 0.0f64;
                let mut replies: Vec<ResponseSlot> = Vec::new();
                for (owner, verts) in by_owner {
                    // Accounting and virtual time at issue — identical on
                    // both transports.
                    let (_b, t) = view.fetch_batch(&mut ledger, machine, owner, &verts);
                    gate = gate.max(timeline.post_comm(t));
                    match fab {
                        None => {
                            // Synchronous path: charge the per-list copy
                            // work straight off the shared CSR.
                            work +=
                                verts.iter().map(|&u| g.degree(u) as u64 / 4 + 1).sum::<u64>();
                        }
                        Some(f) => replies.push(f.issue_fetch(machine, owner, verts)),
                    }
                }
                if let Some(f) = fab {
                    // Pull the working set for real: wait for the owners'
                    // comm threads, then charge the same copy work from
                    // the received payloads (each payload is the owner's
                    // copy of the CSR slice, so the charge is identical).
                    f.flush(machine);
                    for slot in &replies {
                        let resp = f.wait(machine, slot);
                        for i in 0..resp.num_payloads() {
                            work += resp.payload(i).len() as u64 / 4 + 1;
                        }
                    }
                }
                // Local enumeration over the pulled subgraph.
                let (c, w) = enumerate_local(g, plan, v0);
                count += c;
                work += w;
                timeline.post_compute(gate, w as f64 * spu);
                // Release references (GC bookkeeping charged per entry).
                work += CACHE_REQUEST_OVERHEAD_UNITS / 4 * (g.degree(v0) as u64 + 1);
                for &u in std::iter::once(&v0).chain(g.neighbors(v0)) {
                    if let Some(rc) = cache.get_mut(&u) {
                        *rc -= 1;
                        if *rc == 0 {
                            cache.remove(&u);
                        }
                    }
                }
            }
            // The per-task posts covered only the enumeration compute;
            // charge the cache/task management overhead (it runs on the
            // same compute threads) as the remainder.
            let posted: f64 = timeline.compute_busy();
            let all = work as f64 * spu;
            if all > posted {
                timeline.post_compute(0.0, all - posted);
            }
            (count, work, ledger, timeline.finish(), timeline.exposed_comm())
            })
        });

        let mut stats = RunStats::default();
        let mut total = 0u64;
        let mut worst: f64 = 0.0;
        let mut worst_exposed = 0.0f64;
        for (count, work, ledger, finish, exposed) in outcomes {
            total += count;
            stats.work_units += work;
            transport.merge_ledger(&ledger);
            if finish > worst {
                worst = finish;
                worst_exposed = exposed;
            }
        }
        stats.counts = vec![total];
        stats.virtual_time_s = worst;
        stats.exposed_comm_s = worst_exposed;
        stats.network_bytes = transport.traffic.total_bytes();
        stats.network_messages = transport.traffic.total_messages();
        if let Some(f) = &fabric {
            let d = f.diagnostics();
            stats.comm_stall_s = d.stall_s;
            stats.peak_in_flight = d.peak_in_flight;
            stats.comm_flushes = d.flushes;
        }
        stats.wall_s = wall.elapsed().as_secs_f64();
        stats
    }
}

/// Local nested-loop enumeration rooted at `v0` (the user-written
/// pattern-specific code G-thinker requires).
fn enumerate_local(g: &Graph, plan: &Plan, v0: VertexId) -> (u64, u64) {
    use crate::exec;
    use crate::pattern::MAX_PATTERN;
    use crate::plan::Source;

    let mut vertices = [0 as VertexId; MAX_PATTERN];
    vertices[0] = v0;
    let mut count = 0u64;
    let mut work = 0u64;
    let depth = plan.depth();
    let mut stored: Vec<Vec<VertexId>> = vec![Vec::new(); depth];
    #[allow(clippy::too_many_arguments)]
    fn rec(
        g: &Graph,
        plan: &Plan,
        vertices: &mut [VertexId; MAX_PATTERN],
        stored: &mut Vec<Vec<VertexId>>,
        level: usize,
        count: &mut u64,
        work: &mut u64,
        many: &mut exec::MultiScratch,
    ) {
        let depth = plan.depth();
        let step = &plan.steps[level - 1];
        let mut cand: Vec<VertexId> = Vec::new();
        {
            let slices: Vec<&[VertexId]> = step
                .sources
                .iter()
                .map(|s| match *s {
                    Source::Adj(j) => g.neighbors(vertices[j]),
                    Source::Stored(j) => stored[j].as_slice(),
                })
                .collect();
            let w = match slices.len() {
                1 => {
                    cand.extend_from_slice(slices[0]);
                    exec::Work(1)
                }
                2 => exec::intersect(slices[0], slices[1], &mut cand),
                _ => exec::intersect_many(slices[0], &slices[1..], &mut cand, many),
            };
            *work += w.0;
        }
        if !step.exclude.is_empty() {
            let mut tmp = Vec::new();
            for &j in &step.exclude {
                let w = exec::difference(&cand, g.neighbors(vertices[j]), &mut tmp);
                *work += w.0;
                std::mem::swap(&mut cand, &mut tmp);
            }
        }
        let mut lo: VertexId = 0;
        let mut hi: VertexId = VertexId::MAX;
        for &j in &step.greater_than {
            lo = lo.max(vertices[j].saturating_add(1));
        }
        for &j in &step.less_than {
            hi = hi.min(vertices[j]);
        }
        let start = cand.partition_point(|&v| v < lo);
        let end = cand.partition_point(|&v| v < hi);
        if level == depth - 1 {
            let mut c = (end.max(start) - start) as u64;
            for &u in &vertices[..level] {
                if u >= lo && u < hi && cand[start..end].binary_search(&u).is_ok() {
                    c -= 1;
                }
            }
            *count += c;
            *work += (end.max(start) - start) as u64 + 1;
        } else {
            if plan.store_set[level] {
                stored[level] = cand.clone();
            }
            for k in start..end {
                let v = cand[k];
                if vertices[..level].contains(&v) {
                    continue;
                }
                vertices[level] = v;
                rec(g, plan, vertices, stored, level + 1, count, work, many);
            }
        }
    }
    let mut many = exec::MultiScratch::default();
    rec(g, plan, &mut vertices, &mut stored, 1, &mut count, &mut work, &mut many);
    (count, work)
}

// Heavy under Miri (full engine runs / threads / file I/O): the Miri
// leg covers the light per-module tests and the protocol types.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::metrics::NetModel;
    use crate::partition::PartitionedGraph;
    use crate::pattern::brute::{count_embeddings, Induced};
    use crate::pattern::Pattern;
    use crate::plan::automine_plan;

    #[test]
    fn matches_oracle() {
        let g = gen::erdos_renyi(120, 500, 59);
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let expect = count_embeddings(&g, &Pattern::triangle(), Induced::Edge);
        let pg = PartitionedGraph::new(&g, 4);
        let mut tr = Transport::new(pg, NetModel::default());
        let st =
            GThinker::run(&g, &plan, 1, 0, &CommConfig::default(), &ComputeModel::default(), &mut tr);
        assert_eq!(st.total_count(), expect);
        assert!(st.network_bytes > 0);
    }

    #[test]
    fn message_passing_matches_sync_fetch_bitwise() {
        // The real-message transport and the synchronous shared-view path
        // must agree on every deterministic metric, for any window.
        let g = gen::erdos_renyi(150, 700, 63);
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let run = |comm: CommConfig| {
            let pg = PartitionedGraph::new(&g, 4);
            let mut tr = Transport::new(pg, NetModel::default());
            let st = GThinker::run(&g, &plan, 1, 0, &comm, &ComputeModel::default(), &mut tr);
            (st, tr.traffic)
        };
        let (sync, sync_traffic) =
            run(CommConfig { sync_fetch: true, ..Default::default() });
        for window in [1usize, 4, 64] {
            let (asy, asy_traffic) = run(CommConfig {
                max_in_flight: window,
                batch_bytes: 0,
                sync_fetch: false,
            });
            assert_eq!(sync.counts, asy.counts, "window={window}");
            assert_eq!(sync.work_units, asy.work_units, "window={window}");
            assert_eq!(sync_traffic, asy_traffic, "window={window}: traffic matrix");
            assert_eq!(
                sync.virtual_time_s.to_bits(),
                asy.virtual_time_s.to_bits(),
                "window={window}"
            );
            assert!(asy.comm_flushes > 0, "window={window}: messages actually flowed");
        }
    }

    #[test]
    fn overhead_dominates_on_flat_graphs() {
        // ER graph = pt-like: tiny tasks, cache overhead unamortised.
        let g = gen::erdos_renyi(300, 900, 61);
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let pg = PartitionedGraph::new(&g, 4);
        let mut tr = Transport::new(pg, NetModel::default());
        let gt =
            GThinker::run(&g, &plan, 1, 0, &CommConfig::default(), &ComputeModel::default(), &mut tr);
        // Work must massively exceed the pure enumeration work.
        let pure = crate::baselines::SingleMachine::run(&g, &plan, &ComputeModel::default());
        assert!(
            gt.work_units > 10 * pure.work_units,
            "gthinker {} !>> pure {}",
            gt.work_units,
            pure.work_units
        );
    }
}
