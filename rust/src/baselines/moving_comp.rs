//! "Moving computation to data" baseline (Arabesque-style, paper §3.2,
//! Fig 4a).
//!
//! Level-synchronous BFS over partial embeddings: each extension step is
//! performed on the machine that *owns* the data it needs, so partial
//! embeddings are shipped between machines — together with the extra edge
//! lists the next intersection requires (Fig 4a ships N(0) along with
//! subgraphs (0,2) and (0,3)). The paper's three criticisms are visible
//! directly in this implementation: extensions scatter across machines,
//! extra edge-list payloads ride along, and the synchronous shuffle leaves
//! little room to overlap communication with computation.

use crate::cluster::Transport;
use crate::comm::{CommConfig, CommFabric, ShipEmbeddings};
use crate::exec;
use crate::graph::{Graph, VertexId};
use crate::metrics::{ComputeModel, RunStats};
use crate::pattern::MAX_PATTERN;
use crate::plan::{Plan, Source};

/// A partial embedding in flight. Carries the matched vertices plus the
/// *piggybacked* edge-list bytes the destination needs but does not own.
#[derive(Clone, Debug)]
struct Partial {
    vertices: [VertexId; MAX_PATTERN],
    level: usize,
}

/// Moving-computation-to-data distributed miner.
pub struct MovingComputation;

impl MovingComputation {
    pub fn run(
        g: &Graph,
        plan: &Plan,
        threads: usize,
        comm: &CommConfig,
        compute: &ComputeModel,
        transport: &mut Transport,
    ) -> RunStats {
        // audit: wall-clock — RunStats::wall_s diagnostic, outside the
        // determinism contract.
        let wall = std::time::Instant::now();
        let spu = compute.seconds_per_unit / threads.max(1) as f64;
        let n = transport.num_machines();
        let depth = plan.depth();
        // This baseline is inherently level-synchronous (BSP barriers
        // between shuffles), so it stays serial and uses the split
        // transport's single-ledger convenience path — same ClusterView
        // cost model underneath, so traffic comparisons against the
        // parallel engines remain apples-to-apples. The shuffle itself
        // still flows through the comm layer's typed [`ShipEmbeddings`]
        // messages (one envelope per machine pair per level, matching the
        // accounted message count); a BSP superstep needs no comm server
        // threads — each machine drains its own mailbox at the barrier.
        let fabric = (n > 1 && !comm.sync_fetch).then(|| CommFabric::new(n, *comm));

        // Per-machine frontiers of partial embeddings at the current level.
        let mut frontiers: Vec<Vec<Partial>> = vec![Vec::new(); n];
        for m in 0..n {
            for v in transport.partitioned().owned_vertices(m) {
                let mut vs = [0 as VertexId; MAX_PATTERN];
                vs[0] = v;
                frontiers[m].push(Partial { vertices: vs, level: 0 });
            }
        }
        let mut count = 0u64;
        let mut per_machine_work = vec![0u64; n];
        let mut per_machine_comm_s = vec![0f64; n];
        let mut peak = 0u64;

        for level in 0..depth - 1 {
            let step = &plan.steps[level];
            // The extension at `level+1` is computed on the machine owning
            // the *newest* required adjacency (paper Fig 4a: subgraphs
            // (0,2),(0,3) move to the machine owning N(2),N(3)); earlier
            // sources are piggybacked bytes if not owned there (drawback 2).
            let anchor = step.backward.iter().copied().max().unwrap_or(0);
            // Shuffle phase.
            let mut next_frontiers: Vec<Vec<Partial>> = vec![Vec::new(); n];
            let mut shipped: Vec<Vec<u64>> = vec![vec![0u64; n]; n]; // counts
            let mut extra_bytes: Vec<Vec<u64>> = vec![vec![0u64; n]; n];
            for (m, frontier) in frontiers.iter().enumerate() {
                for p in frontier {
                    let dest = transport.partitioned().owner(p.vertices[anchor]);
                    if dest != m {
                        shipped[m][dest] += 1;
                        // Piggyback every other Adj source the destination
                        // does not own.
                        for s in &step.sources {
                            if let Source::Adj(j) = s {
                                if *j != anchor
                                    && transport.partitioned().owner(p.vertices[*j]) != dest
                                {
                                    extra_bytes[m][dest] +=
                                        g.degree(p.vertices[*j]) as u64 * 4;
                                }
                            }
                        }
                    }
                    next_frontiers[dest].push(p.clone());
                }
            }
            for m in 0..n {
                for d in 0..n {
                    if shipped[m][d] > 0 || extra_bytes[m][d] > 0 {
                        let (_b, t) = transport.ship_embeddings(
                            m,
                            d,
                            shipped[m][d],
                            level + 1,
                            extra_bytes[m][d],
                        );
                        per_machine_comm_s[m] += t;
                        if let Some(f) = &fabric {
                            f.send_ship(
                                m,
                                d,
                                ShipEmbeddings {
                                    count: shipped[m][d],
                                    level: level + 1,
                                    extra_bytes: extra_bytes[m][d],
                                },
                            );
                        }
                    }
                }
            }
            // Synchronous barrier: everyone waits for the shuffle. Each
            // machine receives its shipped embeddings off the wire; the
            // received counts must reconcile with what was sent (a cheap
            // end-to-end check that the messages really flowed).
            if let Some(f) = &fabric {
                for d in 0..n {
                    let received: u64 = f.recv_ships(d).iter().map(|s| s.count).sum();
                    let sent: u64 = (0..n).filter(|&m| m != d).map(|m| shipped[m][d]).sum();
                    assert_eq!(received, sent, "machine {d}: shuffle reconciliation");
                }
            }
            // Extension phase (local on each machine).
            frontiers = vec![Vec::new(); n];
            for (m, frontier) in next_frontiers.into_iter().enumerate() {
                peak = peak
                    .max(frontier.len() as u64 * std::mem::size_of::<Partial>() as u64);
                for p in frontier {
                    debug_assert_eq!(p.level, level);
                    let (c, w) =
                        extend_partial(g, plan, &p, level, &mut frontiers[m]);
                    count += c;
                    per_machine_work[m] += w;
                }
            }
        }

        // Virtual time: level-synchronous => per level, slowest machine's
        // compute plus its shuffle time, summed across levels. We
        // approximate with totals (conservative for the baseline).
        let slowest_work = per_machine_work.iter().copied().max().unwrap_or(0);
        let slowest_comm =
            per_machine_comm_s.iter().copied().fold(0.0f64, f64::max);
        let mut out = RunStats::default();
        out.counts = vec![count];
        out.work_units = per_machine_work.iter().sum();
        out.virtual_time_s = slowest_work as f64 * spu + slowest_comm;
        out.exposed_comm_s = slowest_comm; // no overlap in BSP shuffles
        out.network_bytes = transport.traffic.total_bytes();
        out.network_messages = transport.traffic.total_messages();
        out.peak_embedding_bytes = peak;
        if let Some(f) = &fabric {
            out.comm_flushes = f.diagnostics().flushes;
        }
        out.wall_s = wall.elapsed().as_secs_f64();
        out
    }
}

/// Extend one partial embedding by one level; complete embeddings are
/// counted, interior ones pushed to `out`.
fn extend_partial(
    g: &Graph,
    plan: &Plan,
    p: &Partial,
    level: usize,
    out: &mut Vec<Partial>,
) -> (u64, u64) {
    let step = &plan.steps[level];
    let depth = plan.depth();
    let mut work = 0u64;
    let mut cand: Vec<VertexId> = Vec::new();
    {
        // All sources resolve to plain adjacency here: stored-set reuse
        // does not survive shipping (Arabesque ships raw embeddings) — one
        // of the efficiency gaps versus Kudu's hierarchical sharing.
        let slices: Vec<&[VertexId]> = step
            .backward
            .iter()
            .map(|&j| g.neighbors(p.vertices[j]))
            .collect();
        let w = match slices.len() {
            1 => {
                cand.extend_from_slice(slices[0]);
                exec::Work(1)
            }
            2 => exec::intersect(slices[0], slices[1], &mut cand),
            _ => {
                let mut many = exec::MultiScratch::default();
                exec::intersect_many(slices[0], &slices[1..], &mut cand, &mut many)
            }
        };
        work += w.0;
    }
    if !step.exclude.is_empty() {
        let mut tmp = Vec::new();
        for &j in &step.exclude {
            let w = exec::difference(&cand, g.neighbors(p.vertices[j]), &mut tmp);
            work += w.0;
            std::mem::swap(&mut cand, &mut tmp);
        }
    }
    let mut lo: VertexId = 0;
    let mut hi: VertexId = VertexId::MAX;
    for &j in &step.greater_than {
        lo = lo.max(p.vertices[j].saturating_add(1));
    }
    for &j in &step.less_than {
        hi = hi.min(p.vertices[j]);
    }
    let start = cand.partition_point(|&v| v < lo);
    let end = cand.partition_point(|&v| v < hi);
    let new_level = level + 1;
    if new_level == depth - 1 {
        let mut c = (end.max(start) - start) as u64;
        for &u in &p.vertices[..new_level] {
            if u >= lo && u < hi && cand[start..end].binary_search(&u).is_ok() {
                c -= 1;
            }
        }
        work += (end.max(start) - start) as u64 + 1;
        (c, work)
    } else {
        let mut created = 0u64;
        for k in start..end {
            let v = cand[k];
            if p.vertices[..new_level].contains(&v) {
                continue;
            }
            let mut vs = p.vertices;
            vs[new_level] = v;
            out.push(Partial { vertices: vs, level: new_level });
            created += 1;
        }
        work += created * 8; // embedding materialisation cost
        (0, work)
    }
}

// Heavy under Miri (full engine runs / threads / file I/O): the Miri
// leg covers the light per-module tests and the protocol types.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::metrics::NetModel;
    use crate::partition::PartitionedGraph;
    use crate::pattern::brute::{count_embeddings, Induced};
    use crate::pattern::Pattern;
    use crate::plan::automine_plan;

    #[test]
    fn matches_oracle() {
        let g = gen::erdos_renyi(100, 400, 67);
        for p in [Pattern::triangle(), Pattern::chain(3)] {
            let plan = automine_plan(&p, Induced::Edge);
            let expect = count_embeddings(&g, &p, Induced::Edge);
            let pg = PartitionedGraph::new(&g, 3);
            let mut tr = Transport::new(pg, NetModel::default());
            let st = MovingComputation::run(
                &g,
                &plan,
                1,
                &CommConfig::default(),
                &ComputeModel::default(),
                &mut tr,
            );
            assert_eq!(st.total_count(), expect, "{p:?}");
        }
    }

    #[test]
    fn ships_embeddings() {
        let g = gen::rmat(8, 8, 71);
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let pg = PartitionedGraph::new(&g, 4);
        let mut tr = Transport::new(pg, NetModel::default());
        let st = MovingComputation::run(
            &g,
            &plan,
            1,
            &CommConfig::default(),
            &ComputeModel::default(),
            &mut tr,
        );
        assert!(st.network_bytes > 0, "shuffling must generate traffic");
        assert!(st.exposed_comm_s > 0.0, "BSP shuffle exposes its comm");
        // The shuffle flowed through typed ship messages (one envelope
        // per accounted modelled message) — unless the environment pinned
        // the synchronous escape hatch (CI determinism matrix).
        if !CommConfig::default().sync_fetch {
            assert_eq!(st.comm_flushes, st.network_messages, "ship envelopes = modelled messages");
        }
    }

    #[test]
    fn ship_messages_match_sync_path_bitwise() {
        let g = gen::rmat(8, 8, 77);
        let plan = automine_plan(&Pattern::triangle(), Induced::Edge);
        let run = |comm: CommConfig| {
            let pg = PartitionedGraph::new(&g, 4);
            let mut tr = Transport::new(pg, NetModel::default());
            let st =
                MovingComputation::run(&g, &plan, 1, &comm, &ComputeModel::default(), &mut tr);
            (st, tr.traffic)
        };
        let (sync, sync_traffic) = run(CommConfig { sync_fetch: true, ..Default::default() });
        let (msg, msg_traffic) = run(CommConfig { sync_fetch: false, ..Default::default() });
        assert_eq!(sync.counts, msg.counts);
        assert_eq!(sync_traffic, msg_traffic, "traffic matrix");
        assert_eq!(sync.virtual_time_s.to_bits(), msg.virtual_time_s.to_bits());
        assert_eq!(sync.comm_flushes, 0);
        assert!(msg.comm_flushes > 0);
    }
}
