"""L2 correctness: the composed model functions and their lowerability.

Checks (1) model outputs vs the oracle on random hot-core matrices, and
(2) that both AOT entry points lower to HLO text cleanly -- the exact
lowering path aot.py uses -- without writing artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def random_adj(n, density, seed):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < density).astype(np.float32)
    a = np.triu(a, 1)
    return jnp.asarray(a + a.T)


def test_dense_core_outputs_match_ref():
    a = random_adj(256, 0.1, 3)
    tri, wedge, edge = model.dense_core(a)
    rt, rw, re_ = ref.dense_counts_ref(a)
    np.testing.assert_allclose(tri, rt, rtol=1e-5)
    np.testing.assert_allclose(wedge, rw, rtol=1e-5)
    np.testing.assert_allclose(edge, re_, rtol=1e-6)
    assert tri.dtype == jnp.float32


def test_pair_intersect_output_shape():
    u = random_adj(256, 0.2, 5)[:32]
    v = random_adj(256, 0.2, 6)[:32]
    (out,) = model.pair_intersect(u, v)
    assert out.shape == (32,)
    np.testing.assert_allclose(out, ref.pair_common_neighbors_ref(u, v), rtol=1e-6)


def test_dense_core_lowers_to_hlo_text():
    spec = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    lowered = jax.jit(model.dense_core).lower(spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # The MXU contraction must survive lowering as a real dot, not a
    # custom-call (which the CPU PJRT client could not run).
    assert "dot(" in text or "dot " in text
    assert "custom-call" not in text.lower().replace("custom_call", "custom-call")


def test_pair_intersect_lowers_to_hlo_text():
    spec = jax.ShapeDtypeStruct((512, 256), jnp.float32)
    lowered = jax.jit(model.pair_intersect).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text


def test_counts_are_integral_on_01_inputs():
    a = random_adj(256, 0.05, 9)
    tri, wedge, edge = model.dense_core(a)
    for x in (tri, wedge, edge):
        v = float(x)
        assert abs(v - round(v)) < 1e-3, f"count {v} not integral"
