"""L1 correctness: Pallas tile kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and densities; every kernel output must match the
reference to float tolerance. This is the gate before aot.py artifacts are
trusted.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense_tiles, ref

jax.config.update("jax_platform_name", "cpu")


def random_adj(n, density, seed):
    """Symmetric 0/1 adjacency with zero diagonal."""
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < density).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    return jnp.asarray(a)


# --- tiled_matmul ---------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_tiled_matmul_matches_jnp(tiles, seed):
    tile = 8  # small tile for fast interpret-mode sweeps
    n = tiles * tile
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    got = dense_tiles.tiled_matmul(x, y, tile=tile, interpret=True)
    np.testing.assert_allclose(got, x @ y, rtol=1e-4, atol=1e-4)


def test_tiled_matmul_rectangular():
    tile = 8
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((24, 8)), jnp.float32)
    got = dense_tiles.tiled_matmul(x, y, tile=tile, interpret=True)
    np.testing.assert_allclose(got, x @ y, rtol=1e-4, atol=1e-4)


def test_tiled_matmul_rejects_misaligned():
    x = jnp.zeros((10, 10), jnp.float32)
    with pytest.raises(AssertionError):
        dense_tiles.tiled_matmul(x, x, tile=8, interpret=True)


# --- masked_sum / rowsums -------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=3),
    density=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_masked_sum_matches_jnp(tiles, density, seed):
    tile = 8
    n = tiles * tile
    a = random_adj(n, density, seed)
    c = random_adj(n, 0.5, seed + 1)
    got = dense_tiles.masked_sum(c, a, tile=tile, interpret=True)
    np.testing.assert_allclose(got, jnp.sum(c * a), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=3),
    density=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_rowsums_matches_jnp(tiles, density, seed):
    tile = 8
    n = tiles * tile
    a = random_adj(n, density, seed)
    got = dense_tiles.rowsums(a, tile=tile, interpret=True)[:, 0]
    np.testing.assert_allclose(got, jnp.sum(a, axis=1), rtol=1e-5, atol=1e-5)


# --- pair intersect -------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=6),
    tiles=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_pair_intersect_matches_ref(b, tiles, seed):
    tile = 8
    n = tiles * tile
    rng = np.random.default_rng(seed)
    u = jnp.asarray((rng.random((b, n)) < 0.4).astype(np.float32))
    v = jnp.asarray((rng.random((b, n)) < 0.4).astype(np.float32))
    got = dense_tiles.pair_intersect_counts(u, v, tile=tile, interpret=True)
    np.testing.assert_allclose(got, ref.pair_common_neighbors_ref(u, v), rtol=1e-6)


# --- the composed dense-core counter --------------------------------------

@settings(max_examples=8, deadline=None)
@given(
    density=st.floats(min_value=0.0, max_value=0.9),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_dense_counts_matches_ref_128(density, seed):
    # One full MXU tile (the artifact uses 2x2 tiles of 128).
    a = random_adj(128, density, seed)
    tri, wedge, edge = dense_tiles.dense_counts(a, interpret=True)
    rt, rw, re_ = ref.dense_counts_ref(a)
    np.testing.assert_allclose(tri, rt, rtol=1e-5)
    np.testing.assert_allclose(wedge, rw, rtol=1e-5)
    np.testing.assert_allclose(edge, re_, rtol=1e-6)


def test_dense_counts_known_small_graph():
    # 4-clique embedded in a 128-pad: 4 triangles, 12 wedges, 6 edges.
    a = np.zeros((128, 128), np.float32)
    for i in range(4):
        for j in range(4):
            if i != j:
                a[i, j] = 1.0
    tri, wedge, edge = dense_tiles.dense_counts(jnp.asarray(a), interpret=True)
    assert float(tri) == 4.0
    assert float(wedge) == 12.0
    assert float(edge) == 6.0


def test_empty_adjacency():
    a = jnp.zeros((128, 128), jnp.float32)
    tri, wedge, edge = dense_tiles.dense_counts(a, interpret=True)
    assert float(tri) == 0.0 and float(wedge) == 0.0 and float(edge) == 0.0
