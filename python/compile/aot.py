"""AOT lowering: JAX/Pallas (L1+L2) -> HLO text artifacts for the Rust
runtime (L3).

HLO *text* is the interchange format, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Run: ``python -m compile.aot --out ../artifacts`` (or ``make artifacts``).
Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Must match rust/src/runtime/mod.rs::DENSE_N.
DENSE_N = 256
# Batch size for the pair-intersect artifact.
PAIR_BATCH = 512


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument("--dense-n", type=int, default=DENSE_N)
    parser.add_argument("--pair-batch", type=int, default=PAIR_BATCH)
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    n = args.dense_n
    adj_spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    emit(model.dense_core, (adj_spec,), os.path.join(args.out, f"dense_core_{n}.hlo.txt"))

    b = args.pair_batch
    rows_spec = jax.ShapeDtypeStruct((b, n), jnp.float32)
    emit(
        model.pair_intersect,
        (rows_spec, rows_spec),
        os.path.join(args.out, f"pair_intersect_{b}x{n}.hlo.txt"),
    )


if __name__ == "__main__":
    main()
