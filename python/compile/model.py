"""L2: the JAX compute graph composed from the L1 Pallas kernels.

This is the module ``aot.py`` lowers to HLO text for the Rust runtime.
The "model" of this systems paper is the dense hot-core counter: the Rust
engine extracts the top-degree induced adjacency (``runtime::HotCore``),
and this graph produces the (triangles, wedges, edges) scalars consumed by
the hybrid TC path (``workloads::tc_hybrid``).

Exports one more entry point, ``pair_intersect``, the batched bitmap
intersection counter -- the TPU analogue of Kudu's per-pair edge-list
intersections, compiled for fixed batch sizes.
"""

import jax.numpy as jnp

from .kernels import dense_tiles


def dense_core(adj):
    """(tri, wedge, edge) as a 3-tuple of f32 scalars.

    Returns a tuple so ``return_tuple=True`` lowering gives the Rust side
    a single tuple literal to unpack.
    """
    tri, wedge, edge = dense_tiles.dense_counts(adj, interpret=True)
    return (
        jnp.asarray(tri, jnp.float32),
        jnp.asarray(wedge, jnp.float32),
        jnp.asarray(edge, jnp.float32),
    )


def pair_intersect(rows_u, rows_v):
    """Batched |N(u) & N(v)| over 0/1 bitmap rows: f32[b]."""
    return (dense_tiles.pair_intersect_counts(rows_u, rows_v, interpret=True),)
