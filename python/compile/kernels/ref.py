"""Pure-jnp reference oracle for the dense-core pattern counters.

Correctness anchor for the Pallas kernels (L1): every kernel must
``assert_allclose`` against these functions at build time (pytest) before
``aot.py`` is allowed to emit artifacts.

Inputs are dense row-major ``f32[n, n]`` adjacency matrices with entries
0.0/1.0, zero diagonal, symmetric -- the hot-vertex induced subgraph
extracted by the Rust engine (``runtime::HotCore``).
"""

import jax.numpy as jnp


def triangles_ref(adj):
    """Triangle count: trace(A^3) / 6 = sum((A@A) * A) / 6."""
    a2 = adj @ adj
    return jnp.sum(a2 * adj) / 6.0


def wedges_ref(adj):
    """Wedge (2-edge path) count: sum_v C(deg v, 2).

    Counts each unordered wedge once (centre + unordered endpoints).
    """
    deg = jnp.sum(adj, axis=1)
    return jnp.sum(deg * (deg - 1.0)) / 2.0


def edges_ref(adj):
    """Edge count: sum(A) / 2."""
    return jnp.sum(adj) / 2.0


def dense_counts_ref(adj):
    """The (triangles, wedges, edges) tuple the artifact must produce."""
    return triangles_ref(adj), wedges_ref(adj), edges_ref(adj)


def pair_common_neighbors_ref(rows_u, rows_v):
    """Batched |N(u) & N(v)| over bitmap rows: sum_j U[b,j]*V[b,j]."""
    return jnp.sum(rows_u * rows_v, axis=-1)
