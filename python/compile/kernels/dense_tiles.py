"""L1 Pallas kernels: dense-tile pattern counting on the hot-vertex core.

Hardware adaptation (DESIGN.md section 2): Kudu's compute hot-spot is sorted
edge-list intersection on a CPU cluster. On a TPU the equivalent insight --
hot high-degree vertices dominate the work -- maps the hot-vertex induced
subgraph to dense adjacency *tiles* and replaces per-pair merges with an
MXU-shaped contraction ``C = A @ A`` followed by an elementwise mask
``C * A``:

* BlockSpec tiles are ``TILE x TILE`` f32 (128 x 128 = 64 KiB per operand
  buffer, 3 operands + accumulator << 16 MiB VMEM), the MXU-native shape.
* The grid is ``(n/T, n/T, n/T)``: program (i, j, k) multiplies tile
  ``A[i,k] @ A[k,j]``, accumulating over k into tile ``C[i,j]`` -- the
  HBM<->VMEM schedule the paper's CPU version expressed with per-thread
  L1-cache-sized buffers.
* Kernels MUST run with ``interpret=True`` here: the CPU PJRT plugin
  cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).

All kernels are checked against ``ref.py`` by ``python/tests/``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile edge. The artifact's n (256) is 2 tiles per side.
TILE = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ y[k,j].

    The k-loop is the innermost grid dimension, so the output tile stays
    resident in VMEM across the accumulation (revisiting schedule).
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def tiled_matmul(x, y, *, tile=TILE, interpret=True):
    """``x @ y`` via the Pallas tile kernel. Shapes must divide `tile`."""
    n, k = x.shape
    k2, m = y.shape
    assert k == k2 and n % tile == 0 and m % tile == 0 and k % tile == 0, (
        f"shapes {x.shape} x {y.shape} must divide tile {tile}"
    )
    grid = (n // tile, m // tile, k // tile)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile, tile), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x, y)


def _masked_sum_kernel(c_ref, a_ref, o_ref):
    """Elementwise mask + tile-local reduction: o += sum(c * a).

    The triangle closure count: wedge paths (A@A) that close an edge (A).
    """
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init():
        o_ref[0, 0] = 0.0

    o_ref[0, 0] += jnp.sum(c_ref[...] * a_ref[...])


def masked_sum(c, a, *, tile=TILE, interpret=True):
    """``sum(c * a)`` via tile-local reductions into a scalar accumulator."""
    n, m = c.shape
    assert c.shape == a.shape and n % tile == 0 and m % tile == 0
    grid = (n // tile, m // tile)
    out = pl.pallas_call(
        _masked_sum_kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        interpret=interpret,
    )(c, a)
    return out[0, 0]


def _rowsum_kernel(a_ref, o_ref):
    """Row sums per tile, accumulated over the column grid axis."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(a_ref[...], axis=1, keepdims=True)


def rowsums(a, *, tile=TILE, interpret=True):
    """Degree vector (row sums) as f32[n, 1]."""
    n, m = a.shape
    assert n % tile == 0 and m % tile == 0
    grid = (n // tile, m // tile)
    return pl.pallas_call(
        _rowsum_kernel,
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tile, 1), lambda i, j: (i, 0)),
        interpret=interpret,
    )(a)


def pair_intersect_counts(rows_u, rows_v, *, tile=TILE, interpret=True):
    """|N(u) & N(v)| for a batch of vertex pairs given 0/1 bitmap rows.

    The direct TPU analogue of the paper's per-pair edge-list intersection:
    one VPU pass over two VMEM-resident rows per pair, no sorted-merge
    control flow.
    """
    b, n = rows_u.shape
    assert rows_v.shape == (b, n) and n % tile == 0
    grid = (b, n // tile)
    out = pl.pallas_call(
        _pair_intersect_partial_kernel,
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j: (i, j)),
            pl.BlockSpec((1, tile), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        interpret=interpret,
    )(rows_u, rows_v)
    return out[:, 0]


def _pair_intersect_partial_kernel(u_ref, v_ref, o_ref):
    """Per-(pair, column-tile) partial intersection accumulation."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(u_ref[...] * v_ref[...], axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense_counts(adj, *, interpret=True):
    """(triangles, wedges, edges) of a dense 0/1 adjacency via the tile
    kernels -- the L2 composition lowered into the AOT artifact.

    triangles = sum((A@A) * A) / 6     (closed wedges / orientations)
    wedges    = sum_v C(deg v, 2)      (from the rowsum kernel)
    edges     = sum(A) / 2
    """
    a2 = tiled_matmul(adj, adj, interpret=interpret)
    tri = masked_sum(a2, adj, interpret=interpret) / 6.0
    deg = rowsums(adj, interpret=interpret)[:, 0]
    wedge = jnp.sum(deg * (deg - 1.0)) / 2.0
    edge = jnp.sum(deg) / 2.0
    return tri, wedge, edge
